//! Stage III: execution-driven online power-gating co-simulation.
//!
//! Stage II picks a banking/gating configuration *offline* from the
//! occupancy trace; by construction that model cannot see the latency
//! feedback of wake-up stalls on execution timing — the optimizer only
//! *bounds* it as wake-latency exposure
//! ([`crate::banking::optimize::wake_exposure_pct`]). This module closes
//! the loop: [`OnlineGateSim`] replays ONE chosen (C, B, α, policy)
//! configuration cycle by cycle against the live Stage-I occupancy
//! stream (it is a [`TraceSink`]) with explicit per-bank state machines
//! ([`BankState`]: Active / Idle / Drowsy / Gated / Waking) and a
//! feedback path where wake-latency stalls *delay every subsequent
//! access* — the time warp a trace-driven model cannot express.
//!
//! Outputs ([`OnlineReport`]):
//!
//! * a **stall-adjusted end-to-end cycle count**
//!   ([`OnlineReport::end_cycles`] = trace cycles + accumulated stalls),
//! * **per-bank state timelines** ([`StateSpan`] sequences, and a
//!   deterministic [`OnlineReport::timeline_csv`] export),
//! * an **energy total** ([`OnlineReport::eval`]) whose accumulators
//!   replicate [`crate::banking::evaluate`] term for term, so with wake
//!   latency forced to 0 ([`OnlineConfig::wake_override`]) the energy is
//!   **bit-identical** to the offline evaluation of the same
//!   configuration (`tests/online_replay.rs` asserts this on prefill,
//!   decode, and serving traces).
//!
//! ## Semantics: schedule replay with timing feedback
//!
//! Gate decisions replay the *same* break-even rule Stage II used
//! ([`GatingPolicy::decider`]) — the co-simulation validates the offline
//! pick, it does not re-optimize. The decision for an idle run is taken
//! when the run closes (the next access to that bank arrives), on the
//! run's *observed* (stall-adjusted) duration; with zero wake latency the
//! observed and trace durations coincide, which is what makes the
//! reconciliation exact. When a closing run *was* gated, the re-activated
//! banks enter [`BankState::Waking`] for the wake latency: all banks
//! rising at one instant wake in parallel (one stall, not one per bank),
//! and the stall pushes every later trace event — and the run's end —
//! forward in time. Stalls therefore compound: a gated bank elsewhere
//! stays gated longer while the machine waits, which is exactly the
//! second-order effect the offline exposure bound misses.
//!
//! The replayed wake latency defaults to the policy's own latency on the
//! organization ([`GatingPolicy::wake_latency_cycles`]: the CACTI
//! `wake_cycles` for full power gating, a single cycle for drowsy
//! retention) and can be overridden per run — the knob behind the
//! stall-monotonicity property and the zero-wake reconciliation test:
//!
//! ```
//! use trapti::api::{ApiContext, ExperimentSpec};
//! use trapti::banking::{evaluate, replay_trace, GatingPolicy, OnlineConfig};
//! use trapti::util::MIB;
//! use trapti::workload::TINY_GQA;
//!
//! let ctx = ApiContext::new();
//! let spec = ExperimentSpec::builder()
//!     .model(TINY_GQA)
//!     .prefill(64)
//!     .accel(trapti::config::tiny())
//!     .build()
//!     .unwrap();
//! let s1 = spec.run_stage1(&ctx).unwrap();
//! // Replay one configuration online with wake stalls disabled: the
//! // energy reconciles bit-for-bit with the offline Stage-II evaluator.
//! let mut cfg = OnlineConfig::new(4 * MIB, 8, 0.9, GatingPolicy::Aggressive);
//! cfg.wake_override = Some(0);
//! let online =
//!     replay_trace(&ctx.cacti, s1.trace(), &s1.result.stats, cfg, spec.freq_ghz())
//!         .unwrap();
//! let offline = evaluate(
//!     &ctx.cacti, s1.trace(), &s1.result.stats,
//!     cfg.capacity, cfg.banks, cfg.alpha, cfg.policy, spec.freq_ghz(),
//! )
//! .unwrap();
//! assert_eq!(online.eval.e_total_j().to_bits(), offline.e_total_j().to_bits());
//! assert_eq!(online.stall_cycles, 0);
//! ```

use std::fmt;

use crate::cacti::{CactiModel, SramCharacterization};
use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::ceil_div;

use super::energy::BankingEval;
use super::policy::{GateDecider, GatingPolicy};
use super::sweep::SweepPoint;

/// The configuration replayed by one [`OnlineGateSim`] run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OnlineConfig {
    pub capacity: u64,
    pub banks: u32,
    pub alpha: f64,
    pub policy: GatingPolicy,
    /// Replayed wake-up latency in cycles. `None` uses the policy's own
    /// latency on this organization
    /// ([`GatingPolicy::wake_latency_cycles`]). The gate *threshold* is
    /// not affected — it always comes from the organization's real
    /// characterization — but decisions apply it to *observed*
    /// (stall-adjusted) idle durations, so a nonzero latency can gate
    /// strictly more runs than the offline schedule as stalls stretch
    /// them. `Some(0)` produces no stalls and therefore replays the
    /// exact offline gate schedule — the reconciliation mode.
    pub wake_override: Option<u64>,
}

impl OnlineConfig {
    pub fn new(capacity: u64, banks: u32, alpha: f64, policy: GatingPolicy) -> Self {
        Self {
            capacity,
            banks,
            alpha,
            policy,
            wake_override: None,
        }
    }

    /// The configuration of an evaluated sweep point (e.g. a Pareto
    /// frontier member being validated online).
    pub fn of_point(point: &SweepPoint) -> Self {
        Self::new(
            point.eval.capacity,
            point.eval.banks,
            point.eval.alpha,
            point.eval.policy,
        )
    }

    /// Compact deterministic label, e.g. `64MiB/B8/a0.90/aggressive`
    /// (the same format as `ConfigKey::label` — one shared definition).
    pub fn label(&self) -> String {
        super::optimize::config_label(self.capacity, self.banks, self.alpha, self.policy)
    }
}

/// Typed Stage-III error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OnlineError {
    /// The replayed trace was never finalized (no end time).
    UnfinalizedTrace { memory: String },
    /// The configuration's capacity is below the observed peak needed
    /// bytes — the Stage-I schedule would not fit, so the replay is
    /// meaningless (same rule as the Stage-II sweep's feasibility
    /// filter).
    InfeasibleCapacity { capacity: u64, peak_needed: u64 },
    /// Malformed configuration (alpha out of range, non-power-of-two
    /// banks — the CACTI constraint).
    InvalidConfig(String),
}

impl fmt::Display for OnlineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OnlineError::UnfinalizedTrace { memory } => write!(
                f,
                "occupancy trace `{memory}` is not finalized; call \
                 OccupancyTrace::finalize(end) before the online replay"
            ),
            OnlineError::InfeasibleCapacity {
                capacity,
                peak_needed,
            } => write!(
                f,
                "capacity {capacity} B is below the observed peak needed \
                 {peak_needed} B; the Stage-I schedule would not fit this \
                 configuration (pick a capacity >= the peak)"
            ),
            OnlineError::InvalidConfig(why) => write!(f, "invalid online config: {why}"),
        }
    }
}

impl std::error::Error for OnlineError {}

/// State of one bank at one instant of the co-simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankState {
    /// Required by the current occupancy level; serving accesses.
    Active,
    /// Not required, but the policy left it powered (leaking).
    Idle,
    /// In drowsy retention (reduced leakage, data retained).
    Drowsy,
    /// Power-gated off (no leakage, contents dropped).
    Gated,
    /// Powering back up after a gated/drowsy period; accesses stall.
    Waking,
}

impl BankState {
    pub fn label(&self) -> &'static str {
        match self {
            BankState::Active => "active",
            BankState::Idle => "idle",
            BankState::Drowsy => "drowsy",
            BankState::Gated => "gated",
            BankState::Waking => "waking",
        }
    }
}

/// One constant-state span `[t0, t1)` of a bank's timeline, in
/// stall-adjusted cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateSpan {
    pub t0: u64,
    pub t1: u64,
    pub state: BankState,
}

impl StateSpan {
    pub fn dt(&self) -> u64 {
        self.t1 - self.t0
    }
}

/// Stage-III output: the offline-comparable energy evaluation plus the
/// timing quantities only an execution-driven model can produce.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    pub config: OnlineConfig,
    /// Energy evaluation over the stall-adjusted run. The float
    /// reductions replicate [`crate::banking::evaluate`] term for term,
    /// so with zero wake latency this is bit-identical to the offline
    /// evaluation of the same configuration.
    pub eval: BankingEval,
    /// Stage-I end time (trace cycles, no stalls).
    pub trace_cycles: u64,
    /// Total cycles the execution stalled waiting for banks to wake.
    pub stall_cycles: u64,
    /// Level-rise instants that had to wake at least one gated/drowsy
    /// bank (banks rising together wake in parallel, so
    /// `stall_cycles == wake_events * wake_cycles`).
    pub wake_events: u64,
    /// Replayed wake-up latency, cycles.
    pub wake_cycles: u64,
    /// Per-bank state timelines in stall-adjusted cycles (empty when the
    /// sim was built with [`OnlineGateSim::with_timeline`]`(false)`).
    pub timelines: Vec<Vec<StateSpan>>,
}

impl OnlineReport {
    /// Stall-adjusted end-to-end cycle count.
    pub fn end_cycles(&self) -> u64 {
        self.trace_cycles + self.stall_cycles
    }

    /// Observed stall share of the run, percent of the trace length
    /// (comparable to the offline wake-exposure bound; 0 for zero-length
    /// runs).
    pub fn stall_pct(&self) -> f64 {
        if self.trace_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.trace_cycles as f64 * 100.0
        }
    }

    pub fn e_total_j(&self) -> f64 {
        self.eval.e_total_j()
    }

    /// Deterministic per-bank state timeline export:
    /// `bank,state,t0_cycles,t1_cycles` rows in bank-major order — the
    /// `repro replay --timeline-csv` artifact (byte-stable across runs;
    /// golden-pinned in `report::tables` tests).
    pub fn timeline_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("bank,state,t0_cycles,t1_cycles\n");
        for (b, spans) in self.timelines.iter().enumerate() {
            for s in spans {
                let _ = writeln!(out, "{b},{},{},{}", s.state.label(), s.t0, s.t1);
            }
        }
        out
    }

    /// Time each bank spent in `state`, adjusted cycles.
    pub fn state_cycles(&self, bank: usize, state: BankState) -> u64 {
        self.timelines
            .get(bank)
            .map(|spans| {
                spans
                    .iter()
                    .filter(|s| s.state == state)
                    .map(StateSpan::dt)
                    .sum()
            })
            .unwrap_or(0)
    }

    /// The report's timelines as WAL-able [`RunEvent`]s, for appending
    /// to an observability log after the co-simulation closes.
    ///
    /// Stage-III outcomes are **retrospective** — a span's `[t0, t1)` is
    /// only known once it closes, long after `t0` — so emitting them
    /// live would violate the stream's non-decreasing-timestamp
    /// contract. Instead every event carries the envelope stamp
    /// [`OnlineReport::end_cycles`] (the log stays monotone: the run's
    /// last trace instant precedes it) while the exact adjusted-cycle
    /// timing lives in the payload (`t0`/`t1`/`at`). Order is
    /// deterministic: bank-major, spans in timeline order, each Waking
    /// span followed by its `WakeStall`. Empty when the sim ran with
    /// `with_timeline(false)`.
    pub fn events(&self) -> Vec<(u64, RunEvent)> {
        let at = self.end_cycles();
        let mut out = Vec::new();
        for (bank, spans) in self.timelines.iter().enumerate() {
            for s in spans {
                out.push((
                    at,
                    RunEvent::BankSpan {
                        bank: bank as u32,
                        state: s.state.label(),
                        t0: s.t0,
                        t1: s.t1,
                    },
                ));
                if s.state == BankState::Waking {
                    out.push((
                        at,
                        RunEvent::WakeStall {
                            bank: bank as u32,
                            at: s.t0,
                            stall_cycles: s.dt(),
                        },
                    ));
                }
            }
        }
        out
    }
}

/// Cycle-level online gating co-simulator for one configuration.
///
/// Feed it a Stage-I occupancy stream — either live, as a [`TraceSink`]
/// (`ExperimentSpec::stream_online`, `ExperimentSpec::serve_online`), or
/// from a materialized trace via [`replay_trace`] — then call
/// [`OnlineGateSim::into_report`] with the run's access statistics.
pub struct OnlineGateSim {
    config: OnlineConfig,
    ch: SramCharacterization,
    decider: GateDecider,
    /// Effective replayed wake latency.
    wake: u64,
    freq_ghz: f64,
    /// Eq. 1 denominator `floor(alpha * C / B)` (same float expression as
    /// the offline paths; 0 = any occupancy pins every bank).
    usable_per_bank: u64,
    /// Which announced memory to consume in sink mode (0 = shared SRAM /
    /// KV arena).
    mem: usize,
    record_timeline: bool,

    // -- dynamic state -------------------------------------------------
    /// Current Eq. 1 level. Starts at `banks` ("everything busy") so the
    /// first segment opens the right idle runs, mirroring the fused
    /// engine.
    level: u32,
    /// Stall-adjusted start of the current constant-level run.
    run_start: u64,
    /// Stall-adjusted open time of each bank's idle run (entry `b`
    /// meaningful iff `b >= level`).
    open_since: Vec<u64>,
    /// Cumulative stall so far; adjusted time = trace time + stall.
    stall: u64,
    /// Σ level · dt over the adjusted run (integer, order-independent).
    active_weighted: u128,
    gated_cycles: u128,
    n_switch: u64,
    wake_events: u64,
    peak_needed: u64,
    /// Pending sink-mode state `(trace t, needed)`.
    pending: (u64, u64),
    started: bool,
    /// Trace end time once the stream finished.
    finished: Option<u64>,
    timelines: Vec<Vec<StateSpan>>,
    /// Per-bank adjusted time up to which the timeline is recorded.
    cursor: Vec<u64>,
}

impl OnlineGateSim {
    /// Build the co-simulator for `config`, consuming memory index 0.
    pub fn new(
        cacti: &CactiModel,
        config: OnlineConfig,
        freq_ghz: f64,
    ) -> Result<Self, OnlineError> {
        Self::for_memory(cacti, config, freq_ghz, 0)
    }

    /// Build the co-simulator consuming the `mem`-th announced memory.
    pub fn for_memory(
        cacti: &CactiModel,
        config: OnlineConfig,
        freq_ghz: f64,
        mem: usize,
    ) -> Result<Self, OnlineError> {
        if !(config.alpha > 0.0 && config.alpha <= 1.0) {
            return Err(OnlineError::InvalidConfig(format!(
                "alpha {} must be in (0, 1]",
                config.alpha
            )));
        }
        if config.banks < 1 || !config.banks.is_power_of_two() {
            return Err(OnlineError::InvalidConfig(format!(
                "banks {} must be a power of two >= 1 (CACTI constraint)",
                config.banks
            )));
        }
        if config.capacity == 0 {
            return Err(OnlineError::InvalidConfig(
                "capacity must be > 0".to_string(),
            ));
        }
        let ch = cacti.characterize(config.capacity, config.banks);
        let decider = config.policy.decider(&ch, freq_ghz);
        let wake = config
            .wake_override
            .unwrap_or_else(|| config.policy.wake_latency_cycles(&ch));
        // Exactly `banks_required`'s denominator (same float expression).
        let usable_per_bank =
            (config.alpha * (config.capacity as f64 / config.banks as f64)).floor() as u64;
        let banks = config.banks as usize;
        Ok(Self {
            config,
            ch,
            decider,
            wake,
            freq_ghz,
            usable_per_bank,
            mem,
            record_timeline: true,
            level: config.banks,
            run_start: 0,
            open_since: vec![0; banks],
            stall: 0,
            active_weighted: 0,
            gated_cycles: 0,
            n_switch: 0,
            wake_events: 0,
            peak_needed: 0,
            pending: (0, 0),
            started: false,
            finished: None,
            timelines: vec![Vec::new(); banks],
            cursor: vec![0; banks],
        })
    }

    /// Enable or disable per-bank timeline recording (on by default;
    /// turn off for long serving replays where only the energy/stall
    /// totals matter).
    pub fn with_timeline(mut self, record: bool) -> Self {
        self.record_timeline = record;
        if !record {
            self.timelines = Vec::new();
            self.cursor = Vec::new();
        }
        self
    }

    /// Effective replayed wake latency, cycles.
    pub fn wake_cycles(&self) -> u64 {
        self.wake
    }

    /// Cumulative stall so far, cycles.
    pub fn stall_cycles(&self) -> u64 {
        self.stall
    }

    /// Peak needed bytes observed so far (sample granularity in sink
    /// mode).
    pub fn peak_needed(&self) -> u64 {
        self.peak_needed
    }

    /// Eq. 1 for one occupancy value (identical to
    /// [`crate::banking::banks_required`] on this configuration).
    #[inline]
    fn level_for(&self, needed: u64) -> u32 {
        if needed == 0 {
            return 0;
        }
        if self.usable_per_bank == 0 {
            return self.config.banks;
        }
        ceil_div(needed, self.usable_per_bank).min(self.config.banks as u64) as u32
    }

    /// The timeline state an acted-on (gated) idle run renders as.
    fn acted_state(&self) -> BankState {
        match self.config.policy {
            GatingPolicy::Drowsy { .. } => BankState::Drowsy,
            _ => BankState::Gated,
        }
    }

    fn push_span(&mut self, bank: u32, t0: u64, t1: u64, state: BankState) {
        if !self.record_timeline || t1 <= t0 {
            return;
        }
        self.timelines[bank as usize].push(StateSpan { t0, t1, state });
    }

    /// Close bank `b`'s idle run at adjusted time `t_adj`. Returns true
    /// iff the run was gated (the bank must wake before serving again).
    fn close_run(&mut self, b: u32, t_adj: u64) -> bool {
        let opened = self.open_since[b as usize];
        let dt = t_adj - opened;
        let gated = dt > 0 && self.decider.gate(dt);
        if self.record_timeline {
            let cur = self.cursor[b as usize];
            self.push_span(b, cur, opened, BankState::Active);
            let state = if gated {
                self.acted_state()
            } else {
                BankState::Idle
            };
            self.push_span(b, opened, t_adj, state);
            self.cursor[b as usize] = t_adj;
        }
        if gated {
            self.gated_cycles += dt as u128;
            self.n_switch += 2;
        }
        gated
    }

    /// Consume the occupancy change at trace-time segment boundary `t0`:
    /// from here until the next boundary `needed` bytes are resident.
    /// Boundaries must be time-ordered and start at 0.
    pub fn step(&mut self, t0: u64, needed: u64) {
        debug_assert!(self.finished.is_none(), "step after finish");
        if !self.started {
            self.started = true;
            debug_assert_eq!(t0, 0, "occupancy streams start at t=0");
        }
        self.peak_needed = self.peak_needed.max(needed);
        let t_adj = t0 + self.stall;
        let new = self.level_for(needed);
        let old = self.level;
        if new == old {
            return;
        }
        self.active_weighted += old as u128 * (t_adj - self.run_start) as u128;
        self.run_start = t_adj;
        self.level = new;
        if new < old {
            // Banks new..old fall idle; open their runs.
            for b in new..old {
                self.open_since[b as usize] = t_adj;
            }
            return;
        }
        // Banks old..new are now required; close their idle runs and wake
        // the gated ones. Rising banks power up in parallel: one wake
        // stall per rise instant, not one per bank.
        let mut any_wake = false;
        for b in old..new {
            any_wake |= self.close_run(b, t_adj);
        }
        if any_wake {
            self.wake_events += 1;
            if self.wake > 0 {
                let wake_end = t_adj + self.wake;
                if self.record_timeline {
                    // Every rising bank reports Waking for the stall
                    // window — banks that were merely idle re-arm
                    // alongside the waking ones.
                    for b in old..new {
                        self.push_span(b, t_adj, wake_end, BankState::Waking);
                        self.cursor[b as usize] = wake_end;
                    }
                }
                // The access — and every subsequent trace event — waits.
                // The waking window counts at the new level (banks are
                // powered and leaking) and extends every other bank's
                // current state, which is why stalls compound.
                self.stall += self.wake;
            }
        }
    }

    /// Seal the run at trace end time `end`: close every open idle run
    /// (no wake — nothing re-activates) and the activity integral.
    pub fn seal(&mut self, end: u64) {
        assert!(self.finished.is_none(), "seal called twice");
        self.finished = Some(end);
        if !self.started {
            // Zero-segment stream (end == 0): nothing was ever active or
            // idle, matching the offline evaluation of an empty trace.
            self.level = 0;
            return;
        }
        let end_adj = end + self.stall;
        for b in self.level..self.config.banks {
            self.close_run(b, end_adj);
        }
        self.active_weighted += self.level as u128 * (end_adj - self.run_start) as u128;
        self.run_start = end_adj;
        if self.record_timeline {
            for b in 0..self.config.banks {
                let cur = self.cursor[b as usize];
                self.push_span(b, cur, end_adj, BankState::Active);
                self.cursor[b as usize] = end_adj;
            }
        }
    }

    /// Assemble the report. `stats` supplies the Eq. 3 dynamic-energy
    /// access counts (the replay does not change access counts — stalls
    /// delay accesses, they do not add any).
    ///
    /// Errors if the configuration's capacity is below the observed peak
    /// (infeasible, mirroring the sweep's capacity filter). Panics if
    /// called before [`OnlineGateSim::seal`] / the sink's `finish` —
    /// library misuse, same contract as `SweepSink::into_points`.
    pub fn into_report(self, stats: &AccessStats) -> Result<OnlineReport, OnlineError> {
        let end = self.finished.expect("seal()/finish() before into_report()");
        if self.config.capacity < self.peak_needed {
            return Err(OnlineError::InfeasibleCapacity {
                capacity: self.config.capacity,
                peak_needed: self.peak_needed,
            });
        }
        let end_adj = end + self.stall;
        let ch = self.ch;
        let cyc_to_s = 1.0 / (self.freq_ghz * 1e9);
        let end_f = end_adj as f64;

        // The float reductions below replicate `banking::evaluate` /
        // `FusedSweep::into_eval` term for term; with zero stall the
        // inputs are identical, so the results are bit-identical.
        let e_dyn = stats.reads as f64 * ch.e_read_j + stats.writes as f64 * ch.e_write_j;
        let avg = if end_adj == 0 {
            0.0
        } else {
            self.active_weighted as f64 / end_f
        };
        let total_bank_cycles = end_f * self.config.banks as f64;
        let retained = self.config.policy.idle_leak_factor();
        let leak_cycles = total_bank_cycles - self.gated_cycles as f64 * (1.0 - retained);
        let e_leak = ch.p_leak_bank_w * leak_cycles * cyc_to_s;
        let per_switch = match self.config.policy {
            GatingPolicy::Drowsy { .. } => ch.e_switch_j * 0.01,
            _ => ch.e_switch_j,
        };
        let e_sw = self.n_switch as f64 * per_switch;

        let eval = BankingEval {
            capacity: self.config.capacity,
            banks: self.config.banks,
            alpha: self.config.alpha,
            policy: self.config.policy,
            e_dyn_j: e_dyn,
            e_leak_j: e_leak,
            e_sw_j: e_sw,
            n_switch: self.n_switch,
            avg_active_banks: avg,
            gated_fraction: if total_bank_cycles > 0.0 {
                self.gated_cycles as f64 / total_bank_cycles
            } else {
                0.0
            },
            area_mm2: ch.area_mm2,
            latency_cycles: ch.latency_cycles,
            characterization: ch,
        };
        Ok(OnlineReport {
            config: self.config,
            eval,
            trace_cycles: end,
            stall_cycles: self.stall,
            wake_events: self.wake_events,
            wake_cycles: self.wake,
            timelines: self.timelines,
        })
    }
}

impl TraceSink for OnlineGateSim {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        assert!(
            self.mem < memories.len(),
            "OnlineGateSim targets memory {} but the run announced {}",
            self.mem,
            memories.len()
        );
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, _obsolete: u64) {
        if mem != self.mem {
            return;
        }
        debug_assert!(t >= self.pending.0, "stream time went backwards");
        if t > self.pending.0 {
            let (t0, n) = self.pending;
            self.step(t0, n);
        }
        // Same-instant updates overwrite: only the final state at an
        // instant is observable, so a transient never counts toward the
        // feasibility peak (matching `OccupancyTrace::peak_needed` and
        // `SweepSink`).
        self.pending = (t, needed);
    }

    fn finish(&mut self, end: u64) {
        let (t0, n) = self.pending;
        // A zero-duration final state still counts toward the peak
        // (sample granularity), even though it adds no segment.
        self.peak_needed = self.peak_needed.max(n);
        if end > t0 {
            self.step(t0, n);
        }
        self.seal(end);
    }
}

/// Replay one configuration against a materialized, finalized trace —
/// the offline-trace twin of the streaming sink. Timelines are recorded;
/// use [`OnlineGateSim::with_timeline`] directly for long replays where
/// only the totals matter.
pub fn replay_trace(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    config: OnlineConfig,
    freq_ghz: f64,
) -> Result<OnlineReport, OnlineError> {
    replay_trace_with(cacti, trace, stats, config, freq_ghz, true)
}

/// [`replay_trace`] with explicit timeline recording control.
pub fn replay_trace_with(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    config: OnlineConfig,
    freq_ghz: f64,
    record_timeline: bool,
) -> Result<OnlineReport, OnlineError> {
    let Some(end) = trace.end_time() else {
        return Err(OnlineError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    };
    let mut sim =
        OnlineGateSim::new(cacti, config, freq_ghz)?.with_timeline(record_timeline);
    for seg in trace.segments() {
        sim.step(seg.t0, seg.needed);
    }
    // Zero-duration final samples set the peak without producing a
    // segment; fold the trace's sample-granularity peak in so the
    // feasibility check matches the sweep's exactly.
    sim.peak_needed = sim.peak_needed.max(trace.peak_needed());
    sim.seal(end);
    sim.into_report(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::energy::evaluate;
    use crate::util::rng::Rng;
    use crate::util::MIB;

    fn cacti() -> CactiModel {
        CactiModel::default()
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 1_000_000,
            writes: 500_000,
            ..Default::default()
        }
    }

    /// Periodic ramp/release trace with long idle tails.
    fn synth_trace(cap: u64, occ: u64, period: u64, cycles: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", cap);
        let mut t = 0;
        while t < cycles {
            tr.record(t, occ, 0);
            tr.record(t + period / 4, 0, 0);
            t += period;
        }
        tr.finalize(cycles);
        tr
    }

    fn random_trace(rng: &mut Rng, cap: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("m", cap);
        let mut t = 0u64;
        for _ in 0..rng.range(1, 120) {
            t += rng.range(1, 50_000);
            let needed = if rng.below(4) == 0 { 0 } else { rng.below(cap + 1) };
            tr.record(t, needed, 0);
        }
        tr.finalize(t + rng.range(1, 10_000));
        tr
    }

    fn policies() -> [GatingPolicy; 4] {
        [
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ]
    }

    fn assert_evals_identical(a: &BankingEval, b: &BankingEval) {
        assert_eq!(a.e_dyn_j.to_bits(), b.e_dyn_j.to_bits());
        assert_eq!(a.e_leak_j.to_bits(), b.e_leak_j.to_bits());
        assert_eq!(a.e_sw_j.to_bits(), b.e_sw_j.to_bits());
        assert_eq!(a.n_switch, b.n_switch);
        assert_eq!(a.avg_active_banks.to_bits(), b.avg_active_banks.to_bits());
        assert_eq!(a.gated_fraction.to_bits(), b.gated_fraction.to_bits());
    }

    #[test]
    fn zero_wake_replay_is_bit_identical_to_offline_evaluate() {
        let cacti = cacti();
        crate::util::proptest::check("online-zero-wake-reconciliation", 40, |rng| {
            let tr = random_trace(rng, 64 * MIB);
            let st = stats();
            for policy in policies() {
                for &banks in &[1u32, 4, 32] {
                    let mut cfg = OnlineConfig::new(64 * MIB, banks, 0.9, policy);
                    cfg.wake_override = Some(0);
                    let online = replay_trace(&cacti, &tr, &st, cfg, 1.0).unwrap();
                    let offline =
                        evaluate(&cacti, &tr, &st, 64 * MIB, banks, 0.9, policy, 1.0)
                            .unwrap();
                    assert_eq!(online.stall_cycles, 0);
                    assert_eq!(online.end_cycles(), tr.end_time().unwrap());
                    assert_evals_identical(&online.eval, &offline);
                }
            }
        });
    }

    #[test]
    fn wake_stalls_extend_the_run_and_pay_leakage() {
        let cacti = cacti();
        let tr = synth_trace(64 * MIB, 20 * MIB, 1_000_000, 50_000_000);
        let st = stats();
        let cfg = OnlineConfig::new(64 * MIB, 8, 0.9, GatingPolicy::Aggressive);
        let r = replay_trace(&cacti, &tr, &st, cfg, 1.0).unwrap();
        assert!(r.wake_events > 0, "periodic trace must trigger wake-ups");
        assert_eq!(r.stall_cycles, r.wake_events * r.wake_cycles);
        assert_eq!(r.end_cycles(), tr.end_time().unwrap() + r.stall_cycles);
        // The stalled run leaks strictly more than the zero-wake replay.
        let mut zero = cfg;
        zero.wake_override = Some(0);
        let z = replay_trace(&cacti, &tr, &st, zero, 1.0).unwrap();
        assert!(r.eval.e_leak_j > z.eval.e_leak_j);
        // Same gate schedule: identical switch counts.
        assert_eq!(r.eval.n_switch, z.eval.n_switch);
    }

    #[test]
    fn stall_is_monotone_in_wake_latency() {
        let cacti = cacti();
        let tr = synth_trace(64 * MIB, 24 * MIB, 500_000, 40_000_000);
        let st = stats();
        for policy in [GatingPolicy::Aggressive, GatingPolicy::drowsy()] {
            let mut prev = 0u64;
            for wake in [0u64, 1, 10, 100, 1_000, 10_000] {
                let mut cfg = OnlineConfig::new(64 * MIB, 8, 0.9, policy);
                cfg.wake_override = Some(wake);
                let r = replay_trace(&cacti, &tr, &st, cfg, 1.0).unwrap();
                assert!(
                    r.stall_cycles >= prev,
                    "{policy:?}: stall {} regressed below {prev} at wake={wake}",
                    r.stall_cycles
                );
                prev = r.stall_cycles;
            }
        }
    }

    #[test]
    fn timelines_tile_the_adjusted_run_per_bank() {
        let cacti = cacti();
        let mut rng = Rng::new(11);
        let tr = random_trace(&mut rng, 32 * MIB);
        let cfg = OnlineConfig::new(32 * MIB, 8, 0.9, GatingPolicy::Aggressive);
        let r = replay_trace(&cacti, &tr, &stats(), cfg, 1.0).unwrap();
        assert_eq!(r.timelines.len(), 8);
        for (b, spans) in r.timelines.iter().enumerate() {
            let mut t = 0u64;
            for s in spans {
                assert_eq!(s.t0, t, "bank {b}: gap before {s:?}");
                assert!(s.t1 > s.t0, "bank {b}: empty span {s:?}");
                t = s.t1;
            }
            assert_eq!(t, r.end_cycles(), "bank {b} timeline must reach the end");
        }
        // Gated time from the timelines reconciles with the evaluation.
        let gated: u64 = (0..8)
            .map(|b| r.state_cycles(b, BankState::Gated))
            .sum();
        let want = (r.eval.gated_fraction * (r.end_cycles() as f64) * 8.0).round() as u64;
        assert_eq!(gated, want);
    }

    #[test]
    fn report_events_mirror_the_timelines() {
        let cacti = cacti();
        let mut rng = Rng::new(11);
        let tr = random_trace(&mut rng, 32 * MIB);
        let cfg = OnlineConfig::new(32 * MIB, 8, 0.9, GatingPolicy::Aggressive);
        let r = replay_trace(&cacti, &tr, &stats(), cfg, 1.0).unwrap();
        let events = r.events();

        let total_spans: usize = r.timelines.iter().map(Vec::len).sum();
        let spans = events
            .iter()
            .filter(|(_, e)| matches!(e, RunEvent::BankSpan { .. }))
            .count();
        let stalls = events
            .iter()
            .filter(|(_, e)| matches!(e, RunEvent::WakeStall { .. }))
            .count();
        let waking: usize = r
            .timelines
            .iter()
            .flatten()
            .filter(|s| s.state == BankState::Waking)
            .count();
        assert_eq!(spans, total_spans, "one BankSpan per timeline span");
        assert_eq!(stalls, waking, "one WakeStall per Waking span");
        assert!(r.wake_events == 0 || stalls > 0);
        // Retrospective envelope: every event is stamped at the
        // stall-adjusted end, keeping any log it lands in monotone.
        assert!(events.iter().all(|(t, _)| *t == r.end_cycles()));
        // Payload timing is exact: stall cycles reconcile with the
        // report's waking-state time.
        let stall_sum: u64 = events
            .iter()
            .filter_map(|(_, e)| match e {
                RunEvent::WakeStall { stall_cycles, .. } => Some(*stall_cycles),
                _ => None,
            })
            .sum();
        let waking_sum: u64 = (0..8)
            .map(|b| r.state_cycles(b, BankState::Waking))
            .sum();
        assert_eq!(stall_sum, waking_sum);
    }

    #[test]
    fn sink_mode_matches_materialized_replay() {
        let cacti = cacti();
        let mut rng = Rng::new(42);
        let tr = random_trace(&mut rng, 48 * MIB);
        let st = stats();
        let cfg = OnlineConfig::new(48 * MIB, 16, 0.9, GatingPolicy::conservative());

        let mut sink = OnlineGateSim::new(&cacti, cfg, 1.0).unwrap();
        sink.begin(&[MemoryDesc {
            name: "m".to_string(),
            capacity: 48 * MIB,
        }]);
        for s in tr.samples() {
            sink.on_sample(0, s.t, s.needed, s.obsolete);
        }
        sink.finish(tr.end_time().unwrap());
        let streamed = sink.into_report(&st).unwrap();
        let materialized = replay_trace(&cacti, &tr, &st, cfg, 1.0).unwrap();
        assert_evals_identical(&streamed.eval, &materialized.eval);
        assert_eq!(streamed.stall_cycles, materialized.stall_cycles);
        assert_eq!(streamed.timelines, materialized.timelines);
        assert_eq!(streamed.timeline_csv(), materialized.timeline_csv());
    }

    #[test]
    fn sink_ignores_other_memories_and_overwrites_same_instant() {
        let cacti = cacti();
        let cfg = OnlineConfig::new(MIB, 2, 1.0, GatingPolicy::Aggressive);
        let mems = [
            MemoryDesc { name: "a".into(), capacity: MIB },
            MemoryDesc { name: "b".into(), capacity: MIB },
        ];
        let mut sink = OnlineGateSim::new(&cacti, cfg, 1.0).unwrap();
        sink.begin(&mems);
        sink.on_sample(0, 10, MIB, 0); // transient, overwritten below
        sink.on_sample(0, 10, 1024, 0);
        sink.on_sample(1, 20, MIB, 0); // other memory: ignored
        sink.on_sample(0, 50_000, 0, 0);
        sink.finish(1_000_000);
        let streamed = sink.into_report(&AccessStats::default()).unwrap();

        let mut tr = OccupancyTrace::new("a", MIB);
        tr.record(10, MIB, 0);
        tr.record(10, 1024, 0);
        tr.record(50_000, 0, 0);
        tr.finalize(1_000_000);
        let reference = replay_trace(&cacti, &tr, &AccessStats::default(), cfg, 1.0)
            .unwrap();
        assert_evals_identical(&streamed.eval, &reference.eval);
        assert_eq!(streamed.stall_cycles, reference.stall_cycles);
    }

    #[test]
    fn infeasible_capacity_is_a_typed_error() {
        let cacti = cacti();
        let tr = synth_trace(64 * MIB, 40 * MIB, 1_000_000, 10_000_000);
        let cfg = OnlineConfig::new(16 * MIB, 4, 0.9, GatingPolicy::Aggressive);
        let err = replay_trace(&cacti, &tr, &stats(), cfg, 1.0).unwrap_err();
        assert!(matches!(err, OnlineError::InfeasibleCapacity { .. }), "{err}");
        assert!(err.to_string().contains("peak"), "{err}");
    }

    #[test]
    fn invalid_configs_and_unfinalized_traces_are_typed_errors() {
        let cacti = cacti();
        let bad_alpha = OnlineConfig::new(MIB, 4, 1.5, GatingPolicy::Aggressive);
        assert!(matches!(
            OnlineGateSim::new(&cacti, bad_alpha, 1.0).unwrap_err(),
            OnlineError::InvalidConfig(_)
        ));
        let bad_banks = OnlineConfig::new(MIB, 3, 0.9, GatingPolicy::Aggressive);
        assert!(matches!(
            OnlineGateSim::new(&cacti, bad_banks, 1.0).unwrap_err(),
            OnlineError::InvalidConfig(_)
        ));
        let tr = OccupancyTrace::new("m", MIB); // never finalized
        let cfg = OnlineConfig::new(MIB, 4, 0.9, GatingPolicy::Aggressive);
        assert_eq!(
            replay_trace(&cacti, &tr, &stats(), cfg, 1.0).unwrap_err(),
            OnlineError::UnfinalizedTrace {
                memory: "m".to_string()
            }
        );
    }

    #[test]
    fn zero_length_trace_replays_to_zero_everything() {
        let cacti = cacti();
        let mut tr = OccupancyTrace::new("m", MIB);
        tr.finalize(0);
        let cfg = OnlineConfig::new(MIB, 8, 0.9, GatingPolicy::Aggressive);
        let r = replay_trace(&cacti, &tr, &AccessStats::default(), cfg, 1.0).unwrap();
        assert_eq!(r.eval.e_total_j(), 0.0);
        assert_eq!(r.stall_cycles, 0);
        assert_eq!(r.end_cycles(), 0);
        assert_eq!(r.stall_pct(), 0.0);
        assert!(r.timelines.iter().all(Vec::is_empty));
    }

    #[test]
    fn drowsy_wakes_in_one_cycle_and_none_never_stalls() {
        let cacti = cacti();
        let tr = synth_trace(64 * MIB, 20 * MIB, 500_000, 20_000_000);
        let st = stats();
        let drowsy =
            replay_trace(&cacti, &tr, &st,
                OnlineConfig::new(64 * MIB, 8, 0.9, GatingPolicy::drowsy()), 1.0)
                .unwrap();
        assert_eq!(drowsy.wake_cycles, 1);
        assert_eq!(drowsy.stall_cycles, drowsy.wake_events);
        let none = replay_trace(&cacti, &tr, &st,
            OnlineConfig::new(64 * MIB, 8, 0.9, GatingPolicy::None), 1.0)
            .unwrap();
        assert_eq!(none.stall_cycles, 0);
        assert_eq!(none.wake_events, 0);
        assert_eq!(none.wake_cycles, 0);
    }

    #[test]
    fn timeline_csv_shape() {
        let cacti = cacti();
        let mut tr = OccupancyTrace::new("m", 100);
        tr.record(10, 60, 0);
        tr.finalize(20);
        let mut cfg = OnlineConfig::new(100, 2, 1.0, GatingPolicy::None);
        cfg.wake_override = Some(0);
        let r = replay_trace(&cacti, &tr, &AccessStats::default(), cfg, 1.0).unwrap();
        let csv = r.timeline_csv();
        assert!(csv.starts_with("bank,state,t0_cycles,t1_cycles\n"), "{csv}");
        // Bank 0: idle [0,10) then active [10,20); bank 1: idle [0,10),
        // active [10,20) (60/50-per-bank needs 2 banks).
        assert!(csv.contains("0,idle,0,10\n"), "{csv}");
        assert!(csv.contains("0,active,10,20\n"), "{csv}");
        assert!(csv.contains("1,idle,0,10\n"), "{csv}");
    }
}
