//! Hierarchy-aware Stage II/III: a banked L1 backed by an L2 spill pool.
//!
//! The flat sweep ([`super::sweep`]) declares any capacity below the
//! trace's peak infeasible. With a hierarchy, such capacities become
//! *spill* candidates instead: the occupancy above the L1 capacity is
//! held in a second-level SRAM pool and migrated across the boundary as
//! the working set breathes. The L1 still runs the ordinary banked
//! sweep — against a trace clamped at its capacity — while the L2 is
//! charged separately: migration traffic at a per-byte energy and
//! leakage only while spill is resident (the pool is power-gated
//! otherwise, the same gating assumption Stage II applies to L1 banks).
//!
//! Degenerate-config rule (the tentpole's bit-identity contract): with
//! `config = None`, or for any capacity at or above the peak, the
//! result wraps the flat engine's output untouched — same `sweep_fused`
//! / `replay_trace_with` call on the same inputs, so every `f64` is
//! `to_bits`-identical to today's flat path. `tests/hierarchy_diff.rs`
//! holds the differential wall.

use crate::cacti::CactiModel;
use crate::trace::{AccessStats, OccupancyTrace};

use super::energy::EnergyError;
use super::fused::sweep_fused;
use super::online::{replay_trace_with, OnlineConfig, OnlineError, OnlineReport};
use super::sweep::{SweepPoint, SweepSpec};

/// Default migration energy: ~2 pJ/byte, an on-chip-interconnect figure
/// between the CACTI SRAM access energies and a DRAM transfer.
pub const DEFAULT_MIGRATE_ENERGY_PER_BYTE_J: f64 = 2e-12;

/// L2 spill-pool description. Part of [`crate::api::ExperimentSpec`]
/// (default-off; joins the spec hash only when present).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HierarchyConfig {
    /// L2 pool capacity in bytes. Spill beyond it is infeasible (the
    /// flat sweep's below-peak rule, lifted one level).
    pub l2_capacity: u64,
    /// Energy per byte crossing the L1/L2 boundary, joules.
    pub migrate_energy_per_byte_j: f64,
}

impl HierarchyConfig {
    pub fn new(l2_capacity: u64) -> Self {
        Self {
            l2_capacity,
            migrate_energy_per_byte_j: DEFAULT_MIGRATE_ENERGY_PER_BYTE_J,
        }
    }
}

/// The L2 side of one spilled candidate: what the flat L1 evaluation
/// cannot see.
#[derive(Debug, Clone, PartialEq)]
pub struct L2Charge {
    /// Peak bytes resident in the L2 pool (`peak_needed - l1_capacity`).
    pub spilled_peak_bytes: u64,
    /// Total bytes migrated across the L1/L2 boundary (both directions).
    pub migrate_bytes: u64,
    /// `migrate_bytes * migrate_energy_per_byte_j`.
    pub e_migrate_j: f64,
    /// L2 leakage while spill is resident (pool gated otherwise).
    pub e_l2_leak_j: f64,
    /// Cycles with any spill resident in the L2.
    pub l2_resident_cycles: u64,
}

impl L2Charge {
    pub fn e_total_j(&self) -> f64 {
        self.e_migrate_j + self.e_l2_leak_j
    }
}

/// One hierarchy-aware sweep point: the flat L1 evaluation plus the L2
/// charge when this capacity spills (`None` = no spill at this point).
#[derive(Debug, Clone)]
pub struct HierarchyPoint {
    pub point: SweepPoint,
    pub l2: Option<L2Charge>,
}

impl HierarchyPoint {
    /// End-to-end energy: L1 evaluation plus any L2 charge.
    pub fn e_total_j(&self) -> f64 {
        self.point.eval.e_total_j() + self.l2.as_ref().map_or(0.0, L2Charge::e_total_j)
    }

    /// Fold the L2 charge into the flat point so downstream consumers
    /// (pareto/portfolio, report tables) need no hierarchy awareness:
    /// migration is dynamic energy, L2 residence is leakage. With no
    /// spill this returns the inner point unchanged (bit-identical).
    pub fn collapse(self) -> SweepPoint {
        let mut p = self.point;
        if let Some(l2) = self.l2 {
            p.eval.e_dyn_j += l2.e_migrate_j;
            p.eval.e_leak_j += l2.e_l2_leak_j;
        }
        p
    }
}

/// One hierarchy-aware Stage-III replay: the flat online report plus
/// the L2 charge when the configured capacity spills.
#[derive(Debug, Clone)]
pub struct HierarchyReplay {
    pub report: OnlineReport,
    pub l2: Option<L2Charge>,
}

impl HierarchyReplay {
    pub fn e_total_j(&self) -> f64 {
        self.report.e_total_j() + self.l2.as_ref().map_or(0.0, L2Charge::e_total_j)
    }
}

/// Clamp a trace's needed bytes at `cap` (the L1 view of a spilled
/// run). Obsolete bytes only keep whatever L1 room the clamped needed
/// bytes leave — spill space is for required data first.
fn clamp_trace(trace: &OccupancyTrace, cap: u64) -> OccupancyTrace {
    let mut out = OccupancyTrace::new(&trace.memory, cap);
    for s in trace.samples() {
        let needed = s.needed.min(cap);
        let obsolete = s.obsolete.min(cap - needed);
        out.record(s.t, needed, obsolete);
    }
    out.finalize(trace.end_time().expect("caller checked finalization"));
    out
}

/// Charge the L2 side of running `trace` with an L1 of `cap` bytes:
/// migration traffic follows the spill level's changes, leakage accrues
/// only while spill is resident.
fn l2_charge(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    cap: u64,
    cfg: &HierarchyConfig,
    freq_ghz: f64,
) -> L2Charge {
    let mut migrate_bytes = 0u64;
    let mut prev_excess = 0u64;
    for s in trace.samples() {
        let excess = s.needed.saturating_sub(cap);
        migrate_bytes += excess.abs_diff(prev_excess);
        prev_excess = excess;
    }
    let l2_resident_cycles: u64 = trace
        .segments()
        .filter(|seg| seg.needed > cap)
        .map(|seg| seg.dt())
        .sum();
    let resident_s = l2_resident_cycles as f64 / (freq_ghz * 1e9);
    let p_leak_w = cacti.characterize(cfg.l2_capacity, 1).p_leak_total_w();
    L2Charge {
        spilled_peak_bytes: trace.peak_needed().saturating_sub(cap),
        migrate_bytes,
        e_migrate_j: migrate_bytes as f64 * cfg.migrate_energy_per_byte_j,
        e_l2_leak_j: p_leak_w * resident_s,
        l2_resident_cycles,
    }
}

/// Hierarchy-aware Stage-II sweep. `config = None` wraps the flat
/// [`sweep_fused`] output bit-identically (every `l2` is `None`). With
/// a config, capacities at or above the peak still take the flat path
/// verbatim; capacities below it become spill candidates when the
/// excess fits the L2, and are skipped (infeasible) otherwise.
pub fn sweep_hierarchy(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    spec: &SweepSpec,
    freq_ghz: f64,
    config: Option<&HierarchyConfig>,
) -> Result<Vec<HierarchyPoint>, EnergyError> {
    let Some(cfg) = config else {
        return Ok(sweep_fused(cacti, trace, stats, spec, freq_ghz)?
            .into_iter()
            .map(|point| HierarchyPoint { point, l2: None })
            .collect());
    };
    if trace.end_time().is_none() {
        return Err(EnergyError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    }
    let peak = trace.peak_needed();
    let mut out = Vec::with_capacity(spec.points());
    // Per-capacity dispatch preserves the flat engine's output order:
    // capacity-major, then alpha x policy x banks inside the engine.
    for &cap in &spec.capacities {
        let sub = SweepSpec {
            capacities: vec![cap],
            ..spec.clone()
        };
        if cap >= peak {
            // No spill: the literal flat sweep on the original trace —
            // bit-identical to today's path by construction.
            out.extend(
                sweep_fused(cacti, trace, stats, &sub, freq_ghz)?
                    .into_iter()
                    .map(|point| HierarchyPoint { point, l2: None }),
            );
        } else if peak - cap <= cfg.l2_capacity {
            let clamped = clamp_trace(trace, cap);
            let charge = l2_charge(cacti, trace, cap, cfg, freq_ghz);
            out.extend(
                sweep_fused(cacti, &clamped, stats, &sub, freq_ghz)?
                    .into_iter()
                    .map(|point| HierarchyPoint {
                        point,
                        l2: Some(charge.clone()),
                    }),
            );
        }
        // else: the excess exceeds the L2 pool — infeasible, skipped.
    }
    Ok(out)
}

/// Hierarchy-aware Stage-III replay. Without a config — or when the
/// configured L1 capacity already covers the peak — this is the literal
/// flat [`replay_trace_with`] (bit-identical, `l2 = None`). A spilled
/// capacity replays the clamped trace and attaches the L2 charge;
/// spill beyond the L2 pool errors with [`OnlineError::InfeasibleCapacity`]
/// carrying the combined L1+L2 capacity.
pub fn replay_hierarchy(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    config: OnlineConfig,
    freq_ghz: f64,
    record_timeline: bool,
    hierarchy: Option<&HierarchyConfig>,
) -> Result<HierarchyReplay, OnlineError> {
    let peak = trace.peak_needed();
    let cfg = match hierarchy {
        Some(cfg) if config.capacity < peak => cfg,
        _ => {
            let report =
                replay_trace_with(cacti, trace, stats, config, freq_ghz, record_timeline)?;
            return Ok(HierarchyReplay { report, l2: None });
        }
    };
    if trace.end_time().is_none() {
        return Err(OnlineError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    }
    if peak - config.capacity > cfg.l2_capacity {
        return Err(OnlineError::InfeasibleCapacity {
            capacity: config.capacity + cfg.l2_capacity,
            peak_needed: peak,
        });
    }
    let clamped = clamp_trace(trace, config.capacity);
    let charge = l2_charge(cacti, trace, config.capacity, cfg, freq_ghz);
    let report =
        replay_trace_with(cacti, &clamped, stats, config, freq_ghz, record_timeline)?;
    Ok(HierarchyReplay {
        report,
        l2: Some(charge),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::policy::GatingPolicy;
    use crate::util::MIB;

    fn synth_trace() -> OccupancyTrace {
        // Peak 40 MiB, breathing down to 8 MiB.
        let mut tr = OccupancyTrace::new("sram", 128 * MIB);
        let mut t = 0;
        while t < 10_000_000 {
            tr.record(t, 40 * MIB, 0);
            tr.record(t + 300_000, 8 * MIB, MIB);
            t += 600_000;
        }
        tr.finalize(10_000_000);
        tr
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 5_000_000,
            writes: 2_000_000,
            ..Default::default()
        }
    }

    fn grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![16 * MIB, 64 * MIB],
            banks: vec![1, 4],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::None, GatingPolicy::Aggressive],
        }
    }

    #[test]
    fn no_config_is_bitwise_flat() {
        let tr = synth_trace();
        let cacti = CactiModel::default();
        let flat = sweep_fused(&cacti, &tr, &stats(), &grid(), 1.0).unwrap();
        let hier = sweep_hierarchy(&cacti, &tr, &stats(), &grid(), 1.0, None).unwrap();
        assert_eq!(flat.len(), hier.len());
        for (f, h) in flat.iter().zip(&hier) {
            assert!(h.l2.is_none());
            assert_eq!(
                f.eval.e_total_j().to_bits(),
                h.point.eval.e_total_j().to_bits()
            );
        }
    }

    #[test]
    fn spill_capacity_becomes_feasible_and_charges_l2() {
        let tr = synth_trace(); // peak 40 MiB
        let cacti = CactiModel::default();
        let cfg = HierarchyConfig::new(64 * MIB);
        let pts =
            sweep_hierarchy(&cacti, &tr, &stats(), &grid(), 1.0, Some(&cfg)).unwrap();
        // Flat would skip 16 MiB; the hierarchy admits it with spill.
        let spilled: Vec<_> = pts
            .iter()
            .filter(|p| p.point.eval.capacity == 16 * MIB)
            .collect();
        assert_eq!(spilled.len(), 4, "2 policies x 2 banks at the spill cap");
        for p in &spilled {
            let l2 = p.l2.as_ref().expect("below-peak cap must carry L2");
            assert_eq!(l2.spilled_peak_bytes, 24 * MIB);
            assert!(l2.migrate_bytes >= l2.spilled_peak_bytes);
            assert!(l2.e_migrate_j > 0.0 && l2.e_l2_leak_j > 0.0);
            assert!(l2.l2_resident_cycles > 0);
            assert!(p.e_total_j() > p.point.eval.e_total_j());
        }
        // The at-peak capacity stays flat and bit-identical.
        let flat_sub = SweepSpec {
            capacities: vec![64 * MIB],
            ..grid()
        };
        let flat = sweep_fused(&cacti, &tr, &stats(), &flat_sub, 1.0).unwrap();
        let wide: Vec<_> = pts
            .iter()
            .filter(|p| p.point.eval.capacity == 64 * MIB)
            .collect();
        assert_eq!(flat.len(), wide.len());
        for (f, h) in flat.iter().zip(&wide) {
            assert!(h.l2.is_none());
            assert_eq!(
                f.eval.e_total_j().to_bits(),
                h.point.eval.e_total_j().to_bits()
            );
        }
    }

    #[test]
    fn oversized_spill_is_skipped() {
        let tr = synth_trace(); // 16 MiB cap would spill 24 MiB
        let cfg = HierarchyConfig::new(8 * MIB);
        let pts = sweep_hierarchy(
            &CactiModel::default(),
            &tr,
            &stats(),
            &grid(),
            1.0,
            Some(&cfg),
        )
        .unwrap();
        assert!(pts.iter().all(|p| p.point.eval.capacity == 64 * MIB));
    }

    #[test]
    fn collapse_folds_l2_into_energy_components() {
        let tr = synth_trace();
        let cfg = HierarchyConfig::new(64 * MIB);
        let pts = sweep_hierarchy(
            &CactiModel::default(),
            &tr,
            &stats(),
            &grid(),
            1.0,
            Some(&cfg),
        )
        .unwrap();
        for p in pts {
            let total = p.e_total_j();
            let collapsed = p.collapse();
            assert!(
                (collapsed.eval.e_total_j() - total).abs() <= 1e-12 * total.abs().max(1.0),
                "collapse must conserve total energy"
            );
        }
    }

    #[test]
    fn replay_flat_when_capacity_covers_peak() {
        let tr = synth_trace();
        let cacti = CactiModel::default();
        let cfg = HierarchyConfig::new(64 * MIB);
        let config = OnlineConfig::new(64 * MIB, 4, 0.9, GatingPolicy::Aggressive);
        let flat = replay_trace_with(&cacti, &tr, &stats(), config, 1.0, false).unwrap();
        let hier = replay_hierarchy(
            &cacti,
            &tr,
            &stats(),
            config,
            1.0,
            false,
            Some(&cfg),
        )
        .unwrap();
        assert!(hier.l2.is_none());
        assert_eq!(
            flat.e_total_j().to_bits(),
            hier.report.e_total_j().to_bits()
        );
        assert_eq!(flat.stall_cycles, hier.report.stall_cycles);
    }

    #[test]
    fn replay_spill_charges_l2_and_rejects_overflow() {
        let tr = synth_trace();
        let cacti = CactiModel::default();
        let config = OnlineConfig::new(16 * MIB, 4, 0.9, GatingPolicy::Aggressive);
        // Flat replay refuses a below-peak capacity outright.
        assert!(matches!(
            replay_trace_with(&cacti, &tr, &stats(), config, 1.0, false),
            Err(OnlineError::InfeasibleCapacity { .. })
        ));
        // The hierarchy admits it and charges the spill.
        let cfg = HierarchyConfig::new(64 * MIB);
        let rep = replay_hierarchy(&cacti, &tr, &stats(), config, 1.0, false, Some(&cfg))
            .unwrap();
        let l2 = rep.l2.expect("spilled replay must carry L2");
        assert_eq!(l2.spilled_peak_bytes, 24 * MIB);
        assert!(rep.e_total_j() > rep.report.e_total_j());
        // ...but not past the L2 pool.
        let tiny = HierarchyConfig::new(MIB);
        assert!(matches!(
            replay_hierarchy(&cacti, &tr, &stats(), config, 1.0, false, Some(&tiny)),
            Err(OnlineError::InfeasibleCapacity { .. })
        ));
    }

    #[test]
    fn clamped_trace_preserves_timing_and_caps_occupancy() {
        let tr = synth_trace();
        let clamped = clamp_trace(&tr, 16 * MIB);
        assert_eq!(clamped.end_time(), tr.end_time());
        assert_eq!(clamped.peak_needed(), 16 * MIB);
        clamped.validate().unwrap();
    }
}
