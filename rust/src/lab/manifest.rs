//! Declarative lab manifests: one TOML file describing a whole
//! experiment campaign — models × workloads × Stage-II grid ×
//! constraints — expanded by [`crate::lab::planner`] into a job DAG.
//!
//! ```text
//! [lab]
//! name = "tiny"
//! accel = "tiny"                       # named accelerator preset
//! workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8"]
//! validate = true                      # Stage-III validation jobs
//! epsilon = 0.0                        # frontier thinning
//!
//! [grid]                               # omitted -> covering grid
//! capacities = ["2MiB", "4MiB"]        # strings w/ suffix, or raw bytes
//! banks = [1, 2, 4, 8]
//! alphas = [0.9]
//! policies = ["aggressive", "drowsy"]
//!
//! [constraints]                        # all optional
//! max_area_pct = 12.0
//! max_wake_pct = 1.0
//! min_capacity = "2MiB"
//! ```
//!
//! Workload descriptors use the same grammar as `repro optimize
//! --workloads`: `MODEL:prefill:SEQ`, `MODEL:decode:PROMPT:GEN`,
//! `MODEL:serve:REQUESTS:CONCURRENCY:SEED[:bursty]` — [`parse_descriptor`] is
//! the single parser both the CLI and the lab share. The manifest's
//! grid is embedded into every expanded [`ExperimentSpec`], so each
//! spec's FNV content hash — and therefore every job id derived from it
//! — covers the full (model, workload, accelerator, grid) identity.

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::api::optimize::{covering_grid, full_policy_axis};
use crate::api::{validate_sweep, ExperimentSpec};
use crate::banking::optimize::Constraints;
use crate::banking::{GatingPolicy, SweepSpec};
use crate::config::parse::{parse_bytes, Config, Value};
use crate::config::{named, AccelConfig};
use crate::serving::ServingParams;
use crate::workload::{preset, Workload};

/// A parsed lab manifest: the campaign every job id is derived from.
#[derive(Debug, Clone)]
pub struct LabManifest {
    pub name: String,
    pub accel: AccelConfig,
    /// Workload descriptors exactly as written (provenance echo).
    pub descriptors: Vec<String>,
    /// One spec per descriptor, with [`LabManifest::grid`] embedded.
    pub specs: Vec<ExperimentSpec>,
    pub grid: SweepSpec,
    pub constraints: Constraints,
    /// ε for the per-workload frontiers (0 = exact).
    pub epsilon: f64,
    /// Plan Stage-III online-validation jobs (one per workload).
    pub validate: bool,
}

/// Parse one `MODEL:prefill:SEQ` / `MODEL:decode:PROMPT:GEN` /
/// `MODEL:serve:REQUESTS:CONCURRENCY:SEED[:bursty]` workload descriptor
/// into a grid-less spec (the optional `bursty` suffix applies
/// [`ServingParams::with_bursty_traffic`] — MMPP arrivals plus
/// heavy-tailed lengths). Shared by `repro optimize`, `repro replay`,
/// and lab manifests so the descriptor grammar cannot fork.
///
/// Trailing `:mla=DIM` / `:window=N` attention modifiers rewrite the
/// base preset (latent-KV dimension / sliding-window horizon) before the
/// spec builds, so any preset can be swept along the attention spectrum
/// without a dedicated const: `gpt2-xl:decode:512:128:window=256`. The
/// modified preset gets a derived name (`gpt2-xl+w256`), keeping labels
/// and provenance distinct from the base model's.
pub fn parse_descriptor(desc: &str, accel: &AccelConfig) -> Result<ExperimentSpec> {
    let mut parts: Vec<&str> = desc.split(':').collect();
    // Peel attention modifiers off the tail. No base grammar token
    // contains `=`, so any `key=value` tail is either a modifier or a
    // loud error here (never a confusing main-grammar mismatch).
    let (mut latent_dim, mut window): (u32, u32) = (0, 0);
    while let Some(last) = parts.last() {
        let Some((key, val)) = last.split_once('=') else { break };
        let n: u32 = val
            .parse()
            .with_context(|| format!("`{last}` in `{desc}`"))?;
        ensure!(n > 0, "`{last}` in `{desc}`: modifier value must be > 0");
        match key {
            "mla" => latent_dim = n,
            "window" => window = n,
            other => bail!(
                "unknown attention modifier `{other}=` in `{desc}` \
                 (want mla=DIM | window=N)"
            ),
        }
        parts.pop();
    }
    let model_of = |name: &str| -> Result<crate::workload::ModelPreset> {
        let base =
            preset(name).ok_or_else(|| anyhow!("unknown model `{name}` in `{desc}`"))?;
        if latent_dim == 0 && window == 0 {
            return Ok(base);
        }
        let mut m = base;
        m.latent_dim = latent_dim;
        m.window = window;
        // Derived presets need a distinct &'static name for labels and
        // the hashed model identity. The leak is bounded: one small
        // string per parsed descriptor.
        let mut derived = String::from(base.name);
        if latent_dim > 0 {
            derived.push_str(&format!("+mla{latent_dim}"));
        }
        if window > 0 {
            derived.push_str(&format!("+w{window}"));
        }
        m.name = Box::leak(derived.into_boxed_str());
        Ok(m)
    };
    let (model, workload) = match parts.as_slice() {
        [m, "prefill", seq] => (
            model_of(m)?,
            Workload::Prefill { seq: seq.parse()? },
        ),
        [m, "decode", prompt, gen] => (
            model_of(m)?,
            Workload::Decode {
                prompt: prompt.parse()?,
                gen: gen.parse()?,
            },
        ),
        [m, "serve", requests, concurrency, seed] => (
            model_of(m)?,
            Workload::Serving(ServingParams::new(
                requests.parse()?,
                concurrency.parse()?,
                seed.parse()?,
            )),
        ),
        [m, "serve", requests, concurrency, seed, "bursty"] => (
            model_of(m)?,
            Workload::Serving(
                ServingParams::new(
                    requests.parse()?,
                    concurrency.parse()?,
                    seed.parse()?,
                )
                .with_bursty_traffic(),
            ),
        ),
        _ => bail!(
            "workload descriptor `{desc}` wants MODEL:prefill:SEQ | \
             MODEL:decode:PROMPT:GEN | MODEL:serve:REQS:CONC:SEED[:bursty]"
        ),
    };
    ExperimentSpec::builder()
        .model(model)
        .workload(workload)
        .accel(accel.clone())
        .build()
}

/// Parse a gating-policy name (`none|aggressive|conservative|drowsy`)
/// to its canonical policy — the same mapping as `repro replay
/// --policy`, with the paper defaults for the parameterized policies.
pub fn parse_policy_name(name: &str) -> Result<GatingPolicy> {
    match name {
        "none" | "no-gating" => Ok(GatingPolicy::None),
        "aggressive" => Ok(GatingPolicy::Aggressive),
        "conservative" => Ok(GatingPolicy::conservative()),
        "drowsy" => Ok(GatingPolicy::drowsy()),
        other => bail!(
            "unknown policy `{other}` (want none|aggressive|conservative|drowsy)"
        ),
    }
}

/// A byte quantity: a string with a size suffix (`"48MiB"`) or a bare
/// integer of raw bytes.
fn bytes_value(v: &Value, key: &str) -> Result<u64> {
    match v {
        Value::Str(s) => parse_bytes(s).with_context(|| format!("`{key}`")),
        Value::Int(i) if *i >= 0 => Ok(*i as u64),
        _ => bail!("`{key}`: expected a byte size string like \"48MiB\" or raw bytes"),
    }
}

fn opt_array<'c>(cfg: &'c Config, key: &str) -> Result<Option<&'c [Value]>> {
    match cfg.get(key) {
        None => Ok(None),
        Some(Value::Array(items)) => Ok(Some(items)),
        Some(_) => bail!("`{key}`: expected an array"),
    }
}

fn str_items<'v>(items: &'v [Value], key: &str) -> Result<Vec<&'v str>> {
    items
        .iter()
        .map(|v| {
            v.as_str()
                .ok_or_else(|| anyhow!("`{key}`: expected an array of strings"))
        })
        .collect()
}

fn f64_items(items: &[Value], key: &str) -> Result<Vec<f64>> {
    items
        .iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| anyhow!("`{key}`: expected an array of numbers"))
        })
        .collect()
}

fn bool_or(cfg: &Config, key: &str, default: bool) -> Result<bool> {
    match cfg.get(key) {
        None => Ok(default),
        Some(Value::Bool(b)) => Ok(*b),
        Some(_) => bail!("`{key}`: expected true/false"),
    }
}

fn opt_f64(cfg: &Config, key: &str) -> Result<Option<f64>> {
    match cfg.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("`{key}`: expected a number")),
    }
}

impl LabManifest {
    /// Resolve a CLI `--manifest` argument: `@name` is a built-in
    /// manifest ([`crate::api::experiments::lab_manifest`]), anything
    /// else a TOML file path.
    pub fn resolve(source: &str) -> Result<LabManifest> {
        if let Some(name) = source.strip_prefix('@') {
            let text = crate::api::experiments::lab_manifest(name).ok_or_else(|| {
                anyhow!(
                    "unknown built-in lab manifest `@{name}` \
                     (available: @paper, @paired-prefill, @tiny)"
                )
            })?;
            Self::parse(text).with_context(|| format!("built-in manifest @{name}"))
        } else {
            Self::load(Path::new(source))
        }
    }

    pub fn load(path: &Path) -> Result<LabManifest> {
        let cfg = Config::load(path)?;
        Self::of_config(&cfg).with_context(|| format!("lab manifest {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<LabManifest> {
        Self::of_config(&Config::parse(text)?)
    }

    fn of_config(cfg: &Config) -> Result<LabManifest> {
        let name = cfg.str("lab.name")?.to_string();
        let accel_name = cfg.str_or("lab.accel", "baseline");
        let accel = named(accel_name)
            .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;

        let descriptors: Vec<String> = str_items(
            opt_array(cfg, "lab.workloads")?
                .ok_or_else(|| anyhow!("`lab.workloads`: required array"))?,
            "lab.workloads",
        )?
        .into_iter()
        .map(str::to_string)
        .collect();
        ensure!(!descriptors.is_empty(), "`lab.workloads` is empty");

        let mut specs = Vec::with_capacity(descriptors.len());
        for d in &descriptors {
            specs.push(parse_descriptor(d.trim(), &accel)?);
        }
        // Duplicate descriptors would expand to identical job ids — the
        // planner's DAG would silently collapse them; reject up front.
        for i in 0..specs.len() {
            for j in i + 1..specs.len() {
                ensure!(
                    specs[i].content_hash() != specs[j].content_hash(),
                    "duplicate workload `{}` (== `{}`)",
                    descriptors[j],
                    descriptors[i]
                );
            }
        }

        let grid = match opt_array(cfg, "grid.capacities")? {
            Some(caps) => {
                let capacities = caps
                    .iter()
                    .map(|v| bytes_value(v, "grid.capacities"))
                    .collect::<Result<Vec<u64>>>()?;
                let banks: Vec<u32> = match cfg.get("grid.banks") {
                    Some(_) => cfg
                        .u64_array("grid.banks")?
                        .into_iter()
                        .map(|b| u32::try_from(b).context("`grid.banks` out of range"))
                        .collect::<Result<Vec<u32>>>()?,
                    None => vec![1, 2, 4, 8, 16, 32],
                };
                let alphas = match opt_array(cfg, "grid.alphas")? {
                    Some(items) => f64_items(items, "grid.alphas")?,
                    None => vec![0.9],
                };
                let policies = match opt_array(cfg, "grid.policies")? {
                    Some(items) => str_items(items, "grid.policies")?
                        .into_iter()
                        .map(parse_policy_name)
                        .collect::<Result<Vec<_>>>()?,
                    None => full_policy_axis(),
                };
                SweepSpec {
                    capacities,
                    banks,
                    alphas,
                    policies,
                }
            }
            None => {
                if cfg.get("grid.banks").is_some()
                    || cfg.get("grid.alphas").is_some()
                    || cfg.get("grid.policies").is_some()
                {
                    bail!(
                        "[grid] needs `capacities` (without it the lab derives \
                         a covering grid and other grid keys would be dropped)"
                    );
                }
                covering_grid(&specs)
            }
        };
        validate_sweep(&grid)?;
        // Embed the shared grid into every spec: job identity (the spec
        // content hash) then covers the grid, so editing the grid
        // re-keys — and therefore re-runs — every downstream job.
        for spec in &mut specs {
            spec.sweep = Some(grid.clone());
        }

        let constraints = Constraints {
            max_area_overhead_pct: opt_f64(cfg, "constraints.max_area_pct")?,
            max_wake_exposure_pct: opt_f64(cfg, "constraints.max_wake_pct")?,
            min_capacity: match cfg.get("constraints.min_capacity") {
                None => None,
                Some(v) => Some(bytes_value(v, "constraints.min_capacity")?),
            },
        };
        let epsilon = cfg.f64_or("lab.epsilon", 0.0);
        ensure!(epsilon >= 0.0, "`lab.epsilon` must be >= 0");
        let validate = bool_or(cfg, "lab.validate", true)?;

        Ok(LabManifest {
            name,
            accel,
            descriptors,
            specs,
            grid,
            constraints,
            epsilon,
            validate,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    const TINY: &str = r#"
[lab]
name = "unit"
accel = "tiny"
workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8", "tiny-gqa:serve:8:2:7"]
epsilon = 0.25

[grid]
capacities = ["2MiB", 4194304]
banks = [1, 2, 4]
alphas = [0.9]
policies = ["aggressive", "drowsy"]

[constraints]
max_area_pct = 50.0
min_capacity = "2MiB"
"#;

    #[test]
    fn parses_full_manifest() {
        let m = LabManifest::parse(TINY).unwrap();
        assert_eq!(m.name, "unit");
        assert_eq!(m.accel.name, "tiny-test");
        assert_eq!(m.specs.len(), 3);
        assert_eq!(m.grid.capacities, vec![2 * MIB, 4 * MIB]);
        assert_eq!(m.grid.banks, vec![1, 2, 4]);
        assert_eq!(m.grid.policies.len(), 2);
        assert_eq!(m.constraints.max_area_overhead_pct, Some(50.0));
        assert_eq!(m.constraints.min_capacity, Some(2 * MIB));
        assert_eq!(m.constraints.max_wake_exposure_pct, None);
        assert!((m.epsilon - 0.25).abs() < 1e-12);
        assert!(m.validate, "validate defaults on");
        // The grid is embedded into every spec, so content hashes cover it.
        for spec in &m.specs {
            assert_eq!(spec.sweep.as_ref().unwrap().capacities, m.grid.capacities);
        }
        match m.specs[2].workload {
            Workload::Serving(p) => {
                assert_eq!((p.requests, p.concurrency, p.seed), (8, 2, 7));
            }
            _ => panic!("third descriptor is serving"),
        }
    }

    #[test]
    fn grid_defaults_to_covering() {
        let m = LabManifest::parse(
            "[lab]\nname = \"d\"\naccel = \"tiny\"\nworkloads = [\"tiny-mha:prefill:64\"]\n",
        )
        .unwrap();
        // covering_grid floors its capacity axis at 128 MiB in 16 MiB steps.
        assert!(m.grid.capacities.len() >= 8);
        assert_eq!(m.grid.banks, vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(m.grid.policies.len(), 4);
        assert!(!m.validate || m.epsilon == 0.0);
    }

    #[test]
    fn rejects_duplicates_and_orphan_grid_keys() {
        let dup = LabManifest::parse(
            "[lab]\nname = \"d\"\naccel = \"tiny\"\n\
             workloads = [\"tiny-mha:prefill:64\", \"tiny-mha:prefill:64\"]\n",
        );
        assert!(dup.unwrap_err().to_string().contains("duplicate"));
        let orphan = LabManifest::parse(
            "[lab]\nname = \"d\"\naccel = \"tiny\"\nworkloads = [\"tiny-mha:prefill:64\"]\n\
             [grid]\nbanks = [1, 2]\n",
        );
        assert!(orphan.is_err(), "banks without capacities");
    }

    #[test]
    fn descriptor_and_policy_errors_are_loud() {
        let accel = crate::config::tiny();
        assert!(parse_descriptor("tiny-mha:prefill:64", &accel).is_ok());
        assert!(parse_descriptor("nope:prefill:64", &accel).is_err());
        assert!(parse_descriptor("tiny-mha:warmup:64", &accel).is_err());
        assert!(parse_descriptor("tiny-gqa:serve:8:2:7:turbo", &accel).is_err());
    }

    #[test]
    fn bursty_serve_descriptor_enables_the_traffic_extensions() {
        let accel = crate::config::tiny();
        let plain = parse_descriptor("tiny-gqa:serve:8:2:7", &accel).unwrap();
        let bursty = parse_descriptor("tiny-gqa:serve:8:2:7:bursty", &accel).unwrap();
        assert_ne!(plain.content_hash(), bursty.content_hash());
        let Workload::Serving(p) = bursty.workload else {
            panic!("serve descriptor must build a serving workload");
        };
        assert!(p.burst_gap > 0 && p.len_tail_q8 > 0);
        let Workload::Serving(q) = plain.workload else {
            panic!("serve descriptor must build a serving workload");
        };
        assert!(!q.has_extensions());
        assert!(parse_policy_name("drowsy").is_ok());
        assert!(parse_policy_name("extreme").is_err());
    }

    #[test]
    fn attention_modifiers_rewrite_the_preset() {
        let accel = crate::config::tiny();
        let base = parse_descriptor("tiny-mha:decode:16:8", &accel).unwrap();
        let swa = parse_descriptor("tiny-mha:decode:16:8:window=4", &accel).unwrap();
        assert_eq!(swa.model.name, "tiny-mha+w4");
        assert_eq!(swa.model.window, 4);
        assert_eq!(swa.model.latent_dim, 0);
        assert_ne!(base.content_hash(), swa.content_hash());
        // Both modifiers stack, in either order, and feed the builder's
        // latent-dim validation.
        let both =
            parse_descriptor("tiny-mha:decode:16:8:mla=8:window=4", &accel).unwrap();
        assert_eq!(both.model.name, "tiny-mha+mla8+w4");
        assert_eq!((both.model.latent_dim, both.model.window), (8, 4));
        let flipped =
            parse_descriptor("tiny-mha:decode:16:8:window=4:mla=8", &accel).unwrap();
        assert_eq!(flipped.content_hash(), both.content_hash());
        // Errors stay loud: unknown key, zero value, oversized latent.
        assert!(parse_descriptor("tiny-mha:decode:16:8:swa=4", &accel).is_err());
        assert!(parse_descriptor("tiny-mha:decode:16:8:window=0", &accel).is_err());
        assert!(
            parse_descriptor("tiny-mha:decode:16:8:mla=65536", &accel).is_err(),
            "latent wider than the full KV must fail spec validation"
        );
    }

    #[test]
    fn builtin_manifests_parse() {
        for name in ["paper", "paired-prefill", "tiny"] {
            let m = LabManifest::resolve(&format!("@{name}"))
                .unwrap_or_else(|e| panic!("@{name}: {e:#}"));
            assert!(!m.specs.is_empty(), "@{name} has workloads");
        }
        assert!(LabManifest::resolve("@nope").is_err());
    }
}
