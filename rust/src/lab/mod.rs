//! `trapti::lab` — content-addressed experiment lab.
//!
//! Turns the repo from "one CLI invocation per figure" into an
//! experiment manager. Four pieces, one per module:
//!
//! * [`store`] — the on-disk artifact store (`./result/<job-id>/`): a
//!   versioned provenance manifest plus every output artifact per job,
//!   a `COMPLETE` marker written last for crash safety, and a bit-exact
//!   JSON codec for [`crate::banking::optimize::WorkloadSweep`] so
//!   persisted Stage-II tables reload with identical float bits.
//! * [`manifest`] — the declarative TOML lab manifest (`[lab]` +
//!   `[grid]` + `[constraints]`): models × workloads × grid ×
//!   constraints, parsed into [`manifest::LabManifest`] with the grid
//!   embedded into every spec so the FNV spec hash covers it.
//! * [`planner`] — expands a manifest into a deterministic DAG of
//!   Stage I/II/III jobs ([`planner::Plan`]), each keyed by an FNV id
//!   over its inputs; editing an input re-keys exactly the invalidated
//!   downstream jobs.
//! * [`executor`] — the parallel, resumable runner (`--jobs N`,
//!   `--continue-on-failure`): complete jobs are skipped, interrupted
//!   ones wiped and re-run, and determinism makes a resumed run
//!   byte-identical to an uninterrupted one.
//!
//! The CLI surface is `repro lab run|list|gc|trace-params`; built-in
//! manifests (`@paper`, `@paired-prefill`, `@tiny`) live in
//! [`crate::api::experiments::lab_manifest`].

pub mod executor;
pub mod manifest;
pub mod planner;
pub mod store;

pub use executor::{execute, ExecOptions, ExecSummary};
pub use manifest::LabManifest;
pub use planner::{Job, JobKind, Plan};
pub use store::Store;
