//! Parallel, resumable executor for a planned lab DAG.
//!
//! Scheduling is a plain dependency-counting ready queue over
//! `std::thread::scope` workers (`--jobs N`): a job becomes ready when
//! every dependency is `Done` or already complete in the store, and the
//! store's `COMPLETE`-marker protocol ([`crate::lab::store`]) makes the
//! whole thing crash-safe — jobs whose artifacts exist are skipped,
//! interrupted jobs are wiped and re-run, and because every job is
//! bit-deterministic a resumed run converges to the same bytes as an
//! uninterrupted one.
//!
//! Failure policy: by default the first failure cancels everything not
//! yet running (fail-fast); with `continue_on_failure` only the failed
//! job's transitive dependents are cancelled and independent branches
//! keep going. Either way [`execute`] returns a summary, not an error —
//! callers decide how loud to be.
//!
//! Job bodies mirror the `api` layer exactly: sweeps replicate
//! `api::optimize::collect_sweeps` (fused streaming, nothing
//! materialized), and validation *shares*
//! [`crate::api::validate_frontier`] with `api::online_validate` — one
//! materialized Stage-I run, every frontier config replayed across
//! worker threads, rows reassembled in frontier order (byte-identical at
//! any thread count). Validation rebuilds its frontier from its own
//! persisted sweep — a per-workload frontier is independent of the other
//! workloads, so the result is identical to slicing the portfolio run's
//! frontier.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::api::optimize::workload_label;
use crate::api::{
    validate_frontier, ApiContext, ExperimentSpec, MaterializedRun,
};
use crate::banking::optimize::{optimize, OptimizeResult, WorkloadSweep};
use crate::obs::{replay_wal, WalReplay};
use crate::report::tables;
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::json::{self, Json};
use crate::workload::Workload;

use super::manifest::LabManifest;
use super::planner::{Job, JobKind, Plan};
use super::store::{self, Store, LAB_SCHEMA_VERSION};

/// Executor knobs (`repro lab run --jobs N --continue-on-failure 1`).
#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// Worker threads; clamped to at least 1.
    pub jobs: usize,
    /// Keep independent branches running after a failure instead of
    /// cancelling everything not yet started.
    pub continue_on_failure: bool,
    /// Print per-job lifecycle lines to stderr.
    pub progress: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            jobs: 1,
            continue_on_failure: false,
            progress: false,
        }
    }
}

/// What one [`execute`] pass did, in plan (topological) order.
#[derive(Debug, Default)]
pub struct ExecSummary {
    /// Jobs actually run to completion this pass.
    pub executed: Vec<u64>,
    /// Jobs whose artifacts were already complete (pure cache hits).
    pub skipped: Vec<u64>,
    /// Jobs that failed or were cancelled, with the reason.
    pub failed: Vec<(u64, String)>,
}

impl ExecSummary {
    pub fn ok(&self) -> bool {
        self.failed.is_empty()
    }
}

enum St {
    Waiting,
    Ready,
    Running,
    Done,
    Skipped,
    Failed(String),
    Cancelled(String),
}

struct Sched {
    state: Vec<St>,
    /// Reverse edges: job index -> indices depending on it.
    dependents: Vec<Vec<usize>>,
    /// Unfinished-dependency count while `Waiting`.
    remaining: Vec<usize>,
    ready: VecDeque<usize>,
    running: usize,
    finished: usize,
}

/// Run every incomplete job of `plan` against `store`. Returns the
/// pass summary; job failures land in [`ExecSummary::failed`] rather
/// than erroring, so a partial tree is left in a resumable state.
pub fn execute(
    ctx: &ApiContext,
    store: &Store,
    plan: &Plan,
    opts: &ExecOptions,
) -> Result<ExecSummary> {
    let n = plan.jobs.len();
    let index: HashMap<u64, usize> =
        plan.jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();

    let mut sched = Sched {
        state: Vec::with_capacity(n),
        dependents: vec![Vec::new(); n],
        remaining: vec![0; n],
        ready: VecDeque::new(),
        running: 0,
        finished: 0,
    };
    // Prepass in topological order: complete jobs are cache hits; a job
    // whose unfinished-dependency count is zero starts ready.
    for (i, job) in plan.jobs.iter().enumerate() {
        for d in &job.deps {
            let di = *index
                .get(d)
                .ok_or_else(|| anyhow!("{}: dep {} not in plan", job.label, store::hex(*d)))?;
            sched.dependents[di].push(i);
            if !matches!(sched.state[di], St::Skipped) {
                sched.remaining[i] += 1;
            }
        }
        if store.is_complete(job.id) {
            sched.state.push(St::Skipped);
            sched.finished += 1;
        } else if sched.remaining[i] == 0 {
            sched.state.push(St::Ready);
            sched.ready.push_back(i);
        } else {
            sched.state.push(St::Waiting);
        }
    }

    let total = n - sched.finished;
    let sched = Mutex::new(sched);
    let cv = Condvar::new();
    let workers = opts.jobs.max(1).min(n.max(1));

    std::thread::scope(|s| {
        for _ in 0..workers {
            let ctx = ctx.clone();
            let sched = &sched;
            let cv = &cv;
            s.spawn(move || loop {
                // Claim the next ready job, or exit once nothing can
                // ever become ready again.
                let idx = {
                    let mut g = sched.lock().unwrap();
                    loop {
                        if let Some(i) = g.ready.pop_front() {
                            g.state[i] = St::Running;
                            g.running += 1;
                            break i;
                        }
                        if g.running == 0 {
                            return;
                        }
                        g = cv.wait(g).unwrap();
                    }
                };
                let job = &plan.jobs[idx];
                if opts.progress {
                    eprintln!("[lab] run  {} ({})", job.label, store::hex(job.id));
                }
                let res = run_job(&ctx, store, plan, job);
                let mut g = sched.lock().unwrap();
                g.running -= 1;
                g.finished += 1;
                match res {
                    Ok(()) => {
                        if opts.progress {
                            eprintln!(
                                "[lab] done {} ({}/{total})",
                                job.label,
                                g.finished - (n - total)
                            );
                        }
                        g.state[idx] = St::Done;
                        for t in g.dependents[idx].clone() {
                            if matches!(g.state[t], St::Waiting) {
                                g.remaining[t] -= 1;
                                if g.remaining[t] == 0 {
                                    g.state[t] = St::Ready;
                                    g.ready.push_back(t);
                                }
                            }
                        }
                    }
                    Err(e) => {
                        if opts.progress {
                            eprintln!("[lab] FAIL {}: {e:#}", job.label);
                        }
                        g.state[idx] = St::Failed(format!("{e:#}"));
                        if opts.continue_on_failure {
                            // Cancel only the transitive dependents.
                            let mut stack = vec![idx];
                            while let Some(i) = stack.pop() {
                                for t in g.dependents[i].clone() {
                                    if matches!(g.state[t], St::Waiting) {
                                        g.state[t] = St::Cancelled(format!(
                                            "upstream {} failed",
                                            plan.jobs[i].label
                                        ));
                                        g.finished += 1;
                                        stack.push(t);
                                    }
                                }
                            }
                        } else {
                            for i in 0..n {
                                if matches!(g.state[i], St::Waiting | St::Ready) {
                                    g.state[i] = St::Cancelled(
                                        "aborted after failure (use \
                                         --continue-on-failure 1 to keep \
                                         independent jobs running)"
                                            .into(),
                                    );
                                    g.finished += 1;
                                }
                            }
                            g.ready.clear();
                        }
                    }
                }
                cv.notify_all();
            });
        }
    });

    let sched = sched.into_inner().unwrap();
    let mut summary = ExecSummary::default();
    for (i, job) in plan.jobs.iter().enumerate() {
        match &sched.state[i] {
            St::Done => summary.executed.push(job.id),
            St::Skipped => summary.skipped.push(job.id),
            St::Failed(e) | St::Cancelled(e) => summary.failed.push((job.id, e.clone())),
            St::Waiting | St::Ready | St::Running => unreachable!(
                "job {} left non-terminal — scheduler invariant broken",
                job.label
            ),
        }
    }
    Ok(summary)
}

fn run_job(ctx: &ApiContext, store: &Store, plan: &Plan, job: &Job) -> Result<()> {
    store.begin(job.id).with_context(|| job.label.clone())?;
    let artifacts = match job.kind {
        JobKind::Sweep => run_sweep(ctx, store, plan, job),
        JobKind::Optimize => run_optimize(store, plan, job),
        JobKind::Validate => run_validate(ctx, store, plan, job),
    }
    .with_context(|| job.label.clone())?;
    store.finish(job.id, &job_manifest(plan, job, &artifacts))
}

/// Per-job provenance manifest: schema version, identity, dependency
/// edges, the originating spec, and the artifact names. Relative names
/// only — no absolute paths — so two store trees diff clean.
fn job_manifest(plan: &Plan, job: &Job, artifacts: &[&str]) -> Json {
    let mut fields = vec![
        ("schema", Json::num(LAB_SCHEMA_VERSION as u32)),
        ("kind", Json::str(job.kind.label())),
        ("label", Json::str(job.label.clone())),
        ("lab", Json::str(plan.manifest.name.clone())),
        ("job", Json::str(store::hex(job.id))),
        (
            "deps",
            Json::arr(job.deps.iter().map(|d| Json::str(store::hex(*d)))),
        ),
        (
            "artifacts",
            Json::arr(artifacts.iter().map(|a| Json::str(*a))),
        ),
    ];
    if let Some(i) = job.spec_index {
        fields.push(("spec", plan.manifest.specs[i].manifest_json()));
    }
    Json::obj(fields)
}

fn spec_of<'p>(plan: &'p Plan, job: &Job) -> &'p ExperimentSpec {
    &plan.manifest.specs[job.spec_index.expect("spec-bound job")]
}

/// Stage I streamed into the fused Stage-II engine — the exact
/// collection path of `api::optimize::collect_sweeps` for a spec with
/// an embedded grid.
fn collect_sweep(ctx: &ApiContext, spec: &ExperimentSpec) -> Result<WorkloadSweep> {
    let name = workload_label(spec);
    match spec.workload {
        Workload::Serving(_) => {
            let g = spec
                .sweep
                .clone()
                .ok_or_else(|| anyhow!("lab spec lost its embedded grid"))?;
            let (run, s2) = spec.serve_fused_with(ctx, &g)?;
            Ok(WorkloadSweep {
                name,
                end_cycles: run.result.total_cycles,
                points: s2.points,
            })
        }
        _ => {
            let (summary, points) = spec.stream_stage2(ctx)?;
            Ok(WorkloadSweep {
                name,
                end_cycles: summary.total_cycles(),
                points,
            })
        }
    }
}

fn run_sweep(
    ctx: &ApiContext,
    store: &Store,
    plan: &Plan,
    job: &Job,
) -> Result<Vec<&'static str>> {
    let ws = collect_sweep(ctx, spec_of(plan, job))?;
    store.write_artifact(
        job.id,
        "sweep.json",
        store::sweep_to_json(&ws).to_string_pretty().as_bytes(),
    )?;
    store.write_artifact(job.id, "sweep.txt", tables::sweep_table(&ws).render().as_bytes())?;
    Ok(vec!["sweep.json", "sweep.txt"])
}

fn load_sweep(store: &Store, id: u64) -> Result<WorkloadSweep> {
    let bytes = store.read_artifact(id, "sweep.json")?;
    let text = String::from_utf8(bytes).context("sweep.json is not UTF-8")?;
    store::sweep_from_json(&json::parse(&text)?)
        .with_context(|| format!("sweep artifact of job {}", store::hex(id)))
}

/// Deterministic portfolio report — same shape as `repro optimize`'s
/// stdout, derived entirely from persisted sweeps.
fn portfolio_report(m: &LabManifest, r: &OptimizeResult) -> String {
    use std::fmt::Write as _;
    let mut report = String::new();
    let _ = writeln!(
        report,
        "Stage-II Pareto/portfolio optimization: {} workload(s), grid {} \
         points, epsilon={:.3}",
        r.workload_names.len(),
        m.grid.points(),
        r.epsilon,
    );
    for f in &r.frontiers {
        let _ = writeln!(
            report,
            "\n{}: own optimum {} (E={:.3} J over {} cycles)",
            f.workload,
            f.best_key.label(),
            f.best_energy_j,
            f.end_cycles,
        );
        report.push_str(&tables::pareto_table(f).render());
    }
    report.push('\n');
    report.push_str(&tables::portfolio_table(r, 15).render());
    if let Some(best) = r.robust_best() {
        let _ = writeln!(
            report,
            "robust-best across all workloads: {}  (worst regret \
             {:+.1}%, mean {:+.1}%)",
            best.key.label(),
            best.worst_regret_pct,
            best.mean_regret_pct,
        );
    }
    report
}

fn run_optimize(store: &Store, plan: &Plan, job: &Job) -> Result<Vec<&'static str>> {
    let m = &plan.manifest;
    let workloads = job
        .deps
        .iter()
        .map(|&d| load_sweep(store, d))
        .collect::<Result<Vec<_>>>()?;
    let r = optimize(&workloads, &m.constraints, m.epsilon, None)?;
    store.write_artifact(job.id, "pareto.csv", tables::pareto_csv(&r).as_bytes())?;
    store.write_artifact(job.id, "portfolio.txt", portfolio_report(m, &r).as_bytes())?;
    Ok(vec!["pareto.csv", "portfolio.txt"])
}

/// Where a validate job's Stage-I trace came from: replayed from a
/// complete WAL left by an earlier (possibly interrupted-then-restarted)
/// pass, or freshly simulated — in which case the simulation writes the
/// WAL as it runs, so the *next* pass can take the replay path.
enum TraceSource {
    Replayed(WalReplay),
    Fresh(MaterializedRun),
}

impl TraceSource {
    fn trace(&self) -> &OccupancyTrace {
        match self {
            TraceSource::Replayed(r) => &r.traces[0],
            TraceSource::Fresh(run) => run.trace(),
        }
    }

    fn stats(&self) -> &AccessStats {
        match self {
            // `validate_source` only chooses replay when stats landed in
            // the `RunEnd` record, so this cannot fail.
            TraceSource::Replayed(r) => r.stats.as_ref().expect("complete WAL carries stats"),
            TraceSource::Fresh(run) => run.stats(),
        }
    }
}

/// WAL directory for a spec, keyed by content hash under the store
/// root. Not a 16-hex job id at the top level (`.wal/` prefix), so
/// `Store::jobs`/`Store::gc` never touch it, and `Store::begin`'s
/// job-dir wipe cannot destroy an in-flight log.
fn wal_dir_of(store: &Store, spec: &ExperimentSpec) -> std::path::PathBuf {
    store.root().join(".wal").join(store::hex(spec.content_hash()))
}

/// Obtain the validate job's trace: replay the spec's WAL when a
/// complete one exists (no re-simulation), otherwise simulate with the
/// WAL teed in ([`ExperimentSpec::materialize_logged`], `wall_ms = 0`
/// so two store trees stay `diff -r`-clean). Both paths yield
/// bit-identical traces — the replay/materialize equivalence property
/// (`tests/obs_ordering.rs`).
fn validate_source(
    ctx: &ApiContext,
    store: &Store,
    spec: &ExperimentSpec,
) -> Result<TraceSource> {
    let dir = wal_dir_of(store, spec);
    if let Ok(r) = replay_wal(&dir) {
        if r.complete && r.run_id == spec.content_hash() && r.stats.is_some()
            && !r.traces.is_empty()
        {
            return Ok(TraceSource::Replayed(r));
        }
    }
    Ok(TraceSource::Fresh(spec.materialize_logged(ctx, &dir, 0)?))
}

fn run_validate(
    ctx: &ApiContext,
    store: &Store,
    plan: &Plan,
    job: &Job,
) -> Result<Vec<&'static str>> {
    let m = &plan.manifest;
    let spec = spec_of(plan, job);
    let ws = load_sweep(store, job.deps[0])?;
    // Rebuild this workload's frontier from its persisted sweep (the
    // frontier is per-workload, so this equals the portfolio run's).
    let r = optimize(std::slice::from_ref(&ws), &m.constraints, m.epsilon, None)?;
    let frontier = &r.frontiers[0];
    // One Stage-I trace — WAL-replayed or freshly simulated-and-logged —
    // and every frontier config replays against the borrowed trace,
    // exactly `api::online_validate`.
    let run = validate_source(ctx, store, spec)?;
    let vals = validate_frontier(
        &ctx.cacti,
        run.trace(),
        run.stats(),
        frontier,
        spec.freq_ghz(),
        crate::api::optimize::default_validate_jobs(),
    )?;
    store.write_artifact(job.id, "validation.csv", tables::validation_csv(&vals).as_bytes())?;
    store.write_artifact(
        job.id,
        "validation.txt",
        tables::validation_table(&vals).render().as_bytes(),
    )?;
    // Store-root-relative pointer to the run's WAL (the log itself lives
    // outside the job dir so `Store::begin`'s wipe can't lose it).
    let pointer = format!(".wal/{}\n", store::hex(spec.content_hash()));
    store.write_artifact(job.id, "wal", pointer.as_bytes())?;
    Ok(vec!["validation.csv", "validation.txt", "wal"])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::manifest::LabManifest;

    const TEXT: &str = r#"
[lab]
name = "exec-unit"
accel = "tiny"
workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8"]

[grid]
capacities = ["2MiB", "4MiB"]
banks = [1, 2, 4]
alphas = [0.9]
policies = ["aggressive", "drowsy"]
"#;

    fn tmp_store(tag: &str) -> Store {
        let root = std::env::temp_dir()
            .join(format!("trapti-lab-exec-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        Store::new(root)
    }

    #[test]
    fn executes_dag_then_pure_cache_hits() {
        let ctx = ApiContext::new();
        let store = tmp_store("cache");
        let plan = Plan::of(LabManifest::parse(TEXT).unwrap());
        let opts = ExecOptions {
            jobs: 2,
            ..Default::default()
        };
        let first = execute(&ctx, &store, &plan, &opts).unwrap();
        assert!(first.ok(), "{:?}", first.failed);
        assert_eq!(first.executed.len(), plan.jobs.len());
        assert!(first.skipped.is_empty());
        for job in &plan.jobs {
            assert!(store.is_complete(job.id), "{} complete", job.label);
        }
        // Optimize artifacts reload and agree with a fresh in-memory run.
        let opt = plan.jobs.iter().find(|j| j.kind == JobKind::Optimize).unwrap();
        let csv = store.read_artifact(opt.id, "pareto.csv").unwrap();
        assert!(csv.starts_with(b"workload,"), "pareto.csv header");
        // Second pass: zero jobs executed, all cache hits.
        let second = execute(&ctx, &store, &plan, &opts).unwrap();
        assert!(second.executed.is_empty());
        assert_eq!(second.skipped.len(), plan.jobs.len());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn validate_resumes_from_complete_wal() {
        let ctx = ApiContext::new();
        let store = tmp_store("wal");
        let plan = Plan::of(LabManifest::parse(TEXT).unwrap());
        assert!(execute(&ctx, &store, &plan, &ExecOptions::default())
            .unwrap()
            .ok());
        let val = plan.jobs.iter().find(|j| j.kind == JobKind::Validate).unwrap();
        let spec = spec_of(&plan, val);
        // The pass left a complete WAL keyed by spec hash, outside any
        // job dir, and the job carries a pointer artifact to it.
        let replay = replay_wal(&wal_dir_of(&store, spec)).unwrap();
        assert!(replay.complete);
        assert_eq!(replay.run_id, spec.content_hash());
        assert_eq!(
            store.read_artifact(val.id, "wal").unwrap(),
            format!(".wal/{}\n", store::hex(spec.content_hash())).into_bytes()
        );
        // A complete WAL short-circuits re-simulation...
        assert!(matches!(
            validate_source(&ctx, &store, spec).unwrap(),
            TraceSource::Replayed(_)
        ));
        // ...and a wiped-then-rerun job (interrupted-job shape; begin()
        // wipes the dir but not the WAL) regenerates identical bytes.
        let csv = store.read_artifact(val.id, "validation.csv").unwrap();
        run_job(&ctx, &store, &plan, val).unwrap();
        assert_eq!(store.read_artifact(val.id, "validation.csv").unwrap(), csv);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn persisted_sweep_reloads_bit_exact() {
        let ctx = ApiContext::new();
        let store = tmp_store("reload");
        let m = LabManifest::parse(TEXT).unwrap();
        let plan = Plan::of(m);
        let opts = ExecOptions::default();
        assert!(execute(&ctx, &store, &plan, &opts).unwrap().ok());
        let sweep_job = &plan.jobs[0];
        let loaded = load_sweep(&store, sweep_job.id).unwrap();
        let fresh = collect_sweep(&ctx, spec_of(&plan, sweep_job)).unwrap();
        assert_eq!(loaded.name, fresh.name);
        assert_eq!(loaded.end_cycles, fresh.end_cycles);
        assert_eq!(loaded.points.len(), fresh.points.len());
        for (a, b) in loaded.points.iter().zip(&fresh.points) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
            assert_eq!(a.base_e_j.to_bits(), b.base_e_j.to_bits());
        }
        let _ = std::fs::remove_dir_all(store.root());
    }
}
