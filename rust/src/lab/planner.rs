//! Job planner: expand a [`LabManifest`] into a deterministic DAG of
//! Stage I/II/III jobs, each keyed by an FNV-1a id derived from the
//! work it performs.
//!
//! Per manifest the plan is:
//!
//! ```text
//! sweep:<workload>      one per spec  (Stage I streamed into Stage II)
//! optimize:<lab>        one           (depends on every sweep)
//! validate:<workload>   one per spec  (depends on its own sweep only)
//! ```
//!
//! Validation depends only on its workload's sweep — not on the
//! portfolio optimize job — because per-workload frontiers are computed
//! independently by [`crate::banking::optimize::optimize`] (only the
//! portfolio ranking is cross-workload), so a validate job can rebuild
//! its own frontier from its own sweep and run concurrently with
//! everything else.
//!
//! Invalidation is purely structural: a job id hashes the spec content
//! hash (which embeds the grid), the constraints/ε, and every
//! dependency's id. Editing any upstream input therefore re-keys — and
//! re-runs — exactly the affected downstream jobs, while untouched jobs
//! keep their ids and hit the artifact cache.

use std::collections::BTreeSet;

use crate::banking::optimize::Constraints;
use crate::util::Fnv64;

use super::manifest::LabManifest;
use crate::api::optimize::workload_label;

/// Domain-separation key for lab job ids (vs the spec hash's
/// `trapti-spec-v1`). Bump with [`super::store::LAB_SCHEMA_VERSION`] if
/// the job semantics ever change incompatibly.
const LAB_JOB_KEY: &str = "trapti-lab-v1";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Stage I streamed into the fused Stage-II sweep for one workload.
    Sweep,
    /// Cross-workload Pareto/portfolio optimization over every sweep.
    Optimize,
    /// Stage-III online replay of one workload's frontier configs.
    Validate,
}

impl JobKind {
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::Sweep => "sweep",
            JobKind::Optimize => "optimize",
            JobKind::Validate => "validate",
        }
    }
}

/// One schedulable unit of the plan.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub kind: JobKind,
    /// Human-readable `kind:subject` label for listings and logs.
    pub label: String,
    /// Index into [`LabManifest::specs`] (`None` for the optimize job).
    pub spec_index: Option<usize>,
    /// Ids of jobs that must be complete before this one runs.
    pub deps: Vec<u64>,
}

/// A planned manifest: jobs in topological (= execution-safe) order.
#[derive(Debug, Clone)]
pub struct Plan {
    pub manifest: LabManifest,
    pub jobs: Vec<Job>,
}

fn hash_constraints(h: &mut Fnv64, c: &Constraints, epsilon: f64) {
    for opt in [c.max_area_overhead_pct, c.max_wake_exposure_pct] {
        match opt {
            None => h.u64(0),
            Some(v) => {
                h.u64(1);
                h.f64(v);
            }
        }
    }
    match c.min_capacity {
        None => h.u64(0),
        Some(v) => {
            h.u64(1);
            h.u64(v);
        }
    }
    h.f64(epsilon);
}

impl Plan {
    /// Expand a manifest into its job DAG. Deterministic: equal
    /// manifests plan equal ids in equal order.
    pub fn of(manifest: LabManifest) -> Plan {
        let mut jobs = Vec::with_capacity(2 * manifest.specs.len() + 1);
        let mut sweep_ids = Vec::with_capacity(manifest.specs.len());
        for (i, spec) in manifest.specs.iter().enumerate() {
            let mut h = Fnv64::new();
            h.str(LAB_JOB_KEY);
            h.str("sweep");
            // The spec hash covers model, workload, accelerator, AND the
            // embedded grid (see LabManifest::of_config).
            h.u64(spec.content_hash());
            let id = h.finish();
            sweep_ids.push(id);
            jobs.push(Job {
                id,
                kind: JobKind::Sweep,
                label: format!("sweep:{}", workload_label(spec)),
                spec_index: Some(i),
                deps: Vec::new(),
            });
        }

        let mut h = Fnv64::new();
        h.str(LAB_JOB_KEY);
        h.str("optimize");
        hash_constraints(&mut h, &manifest.constraints, manifest.epsilon);
        h.u64(sweep_ids.len() as u64);
        for &id in &sweep_ids {
            h.u64(id);
        }
        jobs.push(Job {
            id: h.finish(),
            kind: JobKind::Optimize,
            label: format!("optimize:{}", manifest.name),
            spec_index: None,
            deps: sweep_ids.clone(),
        });

        if manifest.validate {
            for (i, spec) in manifest.specs.iter().enumerate() {
                let mut h = Fnv64::new();
                h.str(LAB_JOB_KEY);
                h.str("validate");
                h.u64(spec.content_hash());
                hash_constraints(&mut h, &manifest.constraints, manifest.epsilon);
                h.u64(sweep_ids[i]);
                jobs.push(Job {
                    id: h.finish(),
                    kind: JobKind::Validate,
                    label: format!("validate:{}", workload_label(spec)),
                    spec_index: Some(i),
                    deps: vec![sweep_ids[i]],
                });
            }
        }
        Plan { manifest, jobs }
    }

    pub fn job(&self, id: u64) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }

    /// Every job id this plan can reach — the liveness set `lab gc`
    /// preserves.
    pub fn live_ids(&self) -> BTreeSet<u64> {
        self.jobs.iter().map(|j| j.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::manifest::LabManifest;

    const TEXT: &str = r#"
[lab]
name = "unit"
accel = "tiny"
workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8"]

[grid]
capacities = ["2MiB", "4MiB"]
banks = [1, 2]
alphas = [0.9]
policies = ["aggressive"]
"#;

    fn plan_of(text: &str) -> Plan {
        Plan::of(LabManifest::parse(text).unwrap())
    }

    #[test]
    fn dag_shape_and_topology() {
        let p = plan_of(TEXT);
        // 2 sweeps + 1 optimize + 2 validates, in topological order.
        assert_eq!(p.jobs.len(), 5);
        assert_eq!(p.jobs[0].kind, JobKind::Sweep);
        assert_eq!(p.jobs[1].kind, JobKind::Sweep);
        assert_eq!(p.jobs[2].kind, JobKind::Optimize);
        assert_eq!(p.jobs[2].deps, vec![p.jobs[0].id, p.jobs[1].id]);
        assert_eq!(p.jobs[3].kind, JobKind::Validate);
        assert_eq!(p.jobs[3].deps, vec![p.jobs[0].id]);
        assert_eq!(p.jobs[4].deps, vec![p.jobs[1].id]);
        assert_eq!(p.live_ids().len(), 5, "ids are distinct");
        assert_eq!(p.jobs[0].label, "sweep:tiny-mha-prefill64");
        assert_eq!(p.jobs[2].label, "optimize:unit");
        // Every dep appears earlier than its dependent.
        for (i, j) in p.jobs.iter().enumerate() {
            for d in &j.deps {
                assert!(p.jobs[..i].iter().any(|e| e.id == *d), "{} dep order", j.label);
            }
        }
    }

    #[test]
    fn ids_are_stable_and_input_sensitive() {
        let a = plan_of(TEXT);
        let b = plan_of(TEXT);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.id, y.id, "{} replan-stable", x.label);
        }
        // Grid edit: embedded in the spec hash, so EVERY job re-keys.
        let regrid = plan_of(&TEXT.replace("\"4MiB\"", "\"8MiB\""));
        for (x, y) in a.jobs.iter().zip(&regrid.jobs) {
            assert_ne!(x.id, y.id, "{} re-keys on grid edit", x.label);
        }
        // ε edit: sweeps keep their ids (and artifacts); optimize and
        // validates re-key — the "re-run only invalidated downstream
        // stages" rule.
        let reps = plan_of(&format!("{TEXT}\n")
            .replace("name = \"unit\"", "name = \"unit\"\nepsilon = 0.5"));
        assert_eq!(a.jobs[0].id, reps.jobs[0].id);
        assert_eq!(a.jobs[1].id, reps.jobs[1].id);
        assert_ne!(a.jobs[2].id, reps.jobs[2].id);
        assert_ne!(a.jobs[3].id, reps.jobs[3].id);
    }

    #[test]
    fn validate_off_drops_stage3_jobs() {
        let p = plan_of(&TEXT.replace(
            "accel = \"tiny\"",
            "accel = \"tiny\"\nvalidate = false",
        ));
        assert_eq!(p.jobs.len(), 3);
        assert!(p.jobs.iter().all(|j| j.kind != JobKind::Validate));
    }

    #[test]
    fn job_lookup() {
        let p = plan_of(TEXT);
        let id = p.jobs[2].id;
        assert_eq!(p.job(id).unwrap().label, "optimize:unit");
        assert!(p.job(0xffff_ffff_ffff_ffff).is_none());
    }
}
