//! Content-addressed artifact store: one directory per job under the
//! lab root (default `./result/`), named by the 16-hex-digit FNV job id
//! ([`crate::lab::planner`]).
//!
//! Layout of a finished job directory:
//!
//! ```text
//! result/<16-hex job id>/
//!   manifest.json   # schema version, kind, label, deps, spec provenance
//!   <artifacts>     # sweep.json / sweep.txt / pareto.csv / ...
//!   COMPLETE        # completion marker, written LAST
//! ```
//!
//! Crash safety rests on two rules: every file lands via
//! write-to-temp-then-rename, and the `COMPLETE` marker is the final
//! write of a job. A directory without the marker is an interrupted
//! job; [`Store::begin`] wipes it so the executor regenerates it from
//! scratch (regeneration is bit-deterministic, so a resumed run ends
//! byte-identical to an uninterrupted one — the CI lab gate `diff -r`s
//! exactly this).
//!
//! Artifacts must round-trip **bit-exact**: [`crate::util::json::Json`]
//! numbers are f64, which cannot carry a full u64 or guarantee float
//! round-tripping through decimal text, so every u64 and every f64 (as
//! its IEEE-754 bit pattern) is persisted as a 16-hex-digit string.
//! Artifacts never contain absolute paths, so two store trees built
//! from the same manifest compare equal with `diff -r`.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::banking::optimize::WorkloadSweep;
use crate::banking::{BankingEval, GatingPolicy, SweepPoint};
use crate::cacti::SramCharacterization;
use crate::util::json::{self, Json};

/// Version of the per-job `manifest.json` and artifact JSON schemas.
/// Bump on any incompatible layout change; readers reject mismatches
/// instead of misparsing old trees.
pub const LAB_SCHEMA_VERSION: u64 = 1;

const MANIFEST_FILE: &str = "manifest.json";
const COMPLETE_MARKER: &str = "COMPLETE";

/// Canonical 16-hex-digit rendering of a job id / u64 value.
pub fn hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`hex`]: exactly 16 lowercase hex digits.
pub fn parse_hex(s: &str) -> Result<u64> {
    ensure!(
        s.len() == 16 && s.bytes().all(|b| b.is_ascii_hexdigit()),
        "`{s}` is not a 16-hex-digit id"
    );
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad hex `{s}`: {e}"))
}

fn hex_json(v: u64) -> Json {
    Json::str(hex(v))
}

fn bits_json(v: f64) -> Json {
    hex_json(v.to_bits())
}

fn get_hex(obj: &Json, key: &str) -> Result<u64> {
    let s = obj
        .expect(key)?
        .as_str()
        .ok_or_else(|| anyhow!("`{key}`: expected a hex string"))?;
    parse_hex(s).with_context(|| format!("field `{key}`"))
}

fn get_bits(obj: &Json, key: &str) -> Result<f64> {
    Ok(f64::from_bits(get_hex(obj, key)?))
}

fn get_u32(obj: &Json, key: &str) -> Result<u32> {
    let v = obj
        .expect(key)?
        .as_u64()
        .ok_or_else(|| anyhow!("`{key}`: expected an unsigned integer"))?;
    u32::try_from(v).with_context(|| format!("field `{key}` out of u32 range"))
}

/// One content-addressed artifact tree rooted at a lab directory.
#[derive(Debug, Clone)]
pub struct Store {
    root: PathBuf,
}

impl Store {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Store { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn job_dir(&self, id: u64) -> PathBuf {
        self.root.join(hex(id))
    }

    pub fn artifact_path(&self, id: u64, name: &str) -> PathBuf {
        self.job_dir(id).join(name)
    }

    /// A job is complete iff both its manifest and the `COMPLETE`
    /// marker exist — the marker is written last, so this is the
    /// crash-safe "artifacts are trustworthy" predicate.
    pub fn is_complete(&self, id: u64) -> bool {
        let dir = self.job_dir(id);
        dir.join(COMPLETE_MARKER).is_file() && dir.join(MANIFEST_FILE).is_file()
    }

    /// Start (or restart) a job: wipe any interrupted remains of its
    /// directory and create it fresh. Callers must only `begin` jobs
    /// that are not [`Store::is_complete`].
    pub fn begin(&self, id: u64) -> Result<()> {
        let dir = self.job_dir(id);
        if dir.exists() {
            fs::remove_dir_all(&dir)
                .with_context(|| format!("wiping interrupted job {}", dir.display()))?;
        }
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating job dir {}", dir.display()))?;
        Ok(())
    }

    /// Write one artifact atomically (temp file + rename).
    pub fn write_artifact(&self, id: u64, name: &str, bytes: &[u8]) -> Result<()> {
        let path = self.artifact_path(id, name);
        let tmp = self.artifact_path(id, &format!(".tmp.{name}"));
        fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    pub fn read_artifact(&self, id: u64, name: &str) -> Result<Vec<u8>> {
        let path = self.artifact_path(id, name);
        fs::read(&path).with_context(|| format!("reading {}", path.display()))
    }

    /// Finalize a job: persist its manifest, then — last — the
    /// `COMPLETE` marker. Everything before the marker write is
    /// recoverable; after it the job is immutable cache.
    pub fn finish(&self, id: u64, manifest: &Json) -> Result<()> {
        self.write_artifact(id, MANIFEST_FILE, manifest.to_string_pretty().as_bytes())?;
        self.write_artifact(id, COMPLETE_MARKER, b"")
    }

    /// Parsed manifest of a finished job, schema-checked.
    pub fn manifest(&self, id: u64) -> Result<Json> {
        let bytes = self.read_artifact(id, MANIFEST_FILE)?;
        let text = String::from_utf8(bytes).context("manifest.json is not UTF-8")?;
        let m = json::parse(&text)?;
        let schema = m
            .expect("schema")?
            .as_u64()
            .ok_or_else(|| anyhow!("manifest `schema` is not an integer"))?;
        ensure!(
            schema == LAB_SCHEMA_VERSION,
            "job {} has manifest schema {schema}, this build reads {LAB_SCHEMA_VERSION}",
            hex(id)
        );
        Ok(m)
    }

    /// All job ids present in the store (complete or not), sorted.
    /// A missing root is an empty store, not an error.
    pub fn jobs(&self) -> Result<Vec<u64>> {
        let entries = match fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e).with_context(|| format!("listing {}", self.root.display()))
            }
        };
        let mut ids = Vec::new();
        for entry in entries {
            let entry = entry?;
            if !entry.file_type()?.is_dir() {
                continue;
            }
            if let Some(name) = entry.file_name().to_str() {
                if let Ok(id) = parse_hex(name) {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        Ok(ids)
    }

    /// Remove every job directory NOT in `live` (the ids a manifest's
    /// plan can reach — [`crate::lab::planner::Plan::live_ids`]).
    /// Returns the ids removed. Never touches live jobs, complete or
    /// not, and never touches non-id entries under the root.
    pub fn gc(&self, live: &BTreeSet<u64>) -> Result<Vec<u64>> {
        let mut removed = Vec::new();
        for id in self.jobs()? {
            if live.contains(&id) {
                continue;
            }
            fs::remove_dir_all(self.job_dir(id))
                .with_context(|| format!("gc removing job {}", hex(id)))?;
            removed.push(id);
        }
        Ok(removed)
    }
}

// --- WorkloadSweep artifact codec (sweep.json) ------------------------

fn policy_to_json(p: &GatingPolicy) -> Json {
    let (kind, param) = match *p {
        GatingPolicy::None => ("none", None),
        GatingPolicy::Aggressive => ("aggressive", None),
        GatingPolicy::Conservative { min_idle_factor } => {
            ("conservative", Some(min_idle_factor))
        }
        GatingPolicy::Drowsy { retention_factor } => ("drowsy", Some(retention_factor)),
    };
    let mut fields = vec![("kind", Json::str(kind))];
    if let Some(v) = param {
        fields.push(("param", bits_json(v)));
    }
    Json::obj(fields)
}

fn policy_from_json(j: &Json) -> Result<GatingPolicy> {
    let kind = j
        .expect("kind")?
        .as_str()
        .ok_or_else(|| anyhow!("policy `kind` is not a string"))?;
    Ok(match kind {
        "none" => GatingPolicy::None,
        "aggressive" => GatingPolicy::Aggressive,
        "conservative" => GatingPolicy::Conservative {
            min_idle_factor: get_bits(j, "param")?,
        },
        "drowsy" => GatingPolicy::Drowsy {
            retention_factor: get_bits(j, "param")?,
        },
        other => bail!("unknown persisted policy kind `{other}`"),
    })
}

fn characterization_to_json(ch: &SramCharacterization) -> Json {
    Json::obj(vec![
        ("capacity", hex_json(ch.capacity)),
        ("banks", Json::num(ch.banks)),
        ("e_read_j", bits_json(ch.e_read_j)),
        ("e_write_j", bits_json(ch.e_write_j)),
        ("p_leak_bank_w", bits_json(ch.p_leak_bank_w)),
        ("e_switch_j", bits_json(ch.e_switch_j)),
        ("wake_cycles", hex_json(ch.wake_cycles)),
        ("area_mm2", bits_json(ch.area_mm2)),
        ("latency_cycles", hex_json(ch.latency_cycles)),
    ])
}

fn characterization_from_json(j: &Json) -> Result<SramCharacterization> {
    Ok(SramCharacterization {
        capacity: get_hex(j, "capacity")?,
        banks: get_u32(j, "banks")?,
        e_read_j: get_bits(j, "e_read_j")?,
        e_write_j: get_bits(j, "e_write_j")?,
        p_leak_bank_w: get_bits(j, "p_leak_bank_w")?,
        e_switch_j: get_bits(j, "e_switch_j")?,
        wake_cycles: get_hex(j, "wake_cycles")?,
        area_mm2: get_bits(j, "area_mm2")?,
        latency_cycles: get_hex(j, "latency_cycles")?,
    })
}

fn point_to_json(p: &SweepPoint) -> Json {
    let e = &p.eval;
    Json::obj(vec![
        ("capacity", hex_json(e.capacity)),
        ("banks", Json::num(e.banks)),
        ("alpha", bits_json(e.alpha)),
        ("policy", policy_to_json(&e.policy)),
        ("e_dyn_j", bits_json(e.e_dyn_j)),
        ("e_leak_j", bits_json(e.e_leak_j)),
        ("e_sw_j", bits_json(e.e_sw_j)),
        ("n_switch", hex_json(e.n_switch)),
        ("avg_active_banks", bits_json(e.avg_active_banks)),
        ("gated_fraction", bits_json(e.gated_fraction)),
        ("area_mm2", bits_json(e.area_mm2)),
        ("latency_cycles", hex_json(e.latency_cycles)),
        ("characterization", characterization_to_json(&e.characterization)),
        ("base_e_j", bits_json(p.base_e_j)),
        ("base_area_mm2", bits_json(p.base_area_mm2)),
    ])
}

fn point_from_json(j: &Json) -> Result<SweepPoint> {
    Ok(SweepPoint {
        eval: BankingEval {
            capacity: get_hex(j, "capacity")?,
            banks: get_u32(j, "banks")?,
            alpha: get_bits(j, "alpha")?,
            policy: policy_from_json(j.expect("policy")?)?,
            e_dyn_j: get_bits(j, "e_dyn_j")?,
            e_leak_j: get_bits(j, "e_leak_j")?,
            e_sw_j: get_bits(j, "e_sw_j")?,
            n_switch: get_hex(j, "n_switch")?,
            avg_active_banks: get_bits(j, "avg_active_banks")?,
            gated_fraction: get_bits(j, "gated_fraction")?,
            area_mm2: get_bits(j, "area_mm2")?,
            latency_cycles: get_hex(j, "latency_cycles")?,
            characterization: characterization_from_json(j.expect("characterization")?)?,
        },
        base_e_j: get_bits(j, "base_e_j")?,
        base_area_mm2: get_bits(j, "base_area_mm2")?,
    })
}

/// Persist a Stage-II sweep bit-exactly (every float as its bit
/// pattern, every u64 as hex) so downstream optimize/validate jobs can
/// reload it and reproduce the exact in-memory results.
pub fn sweep_to_json(w: &WorkloadSweep) -> Json {
    Json::obj(vec![
        ("schema", Json::num(LAB_SCHEMA_VERSION as u32)),
        ("name", Json::str(w.name.clone())),
        ("end_cycles", hex_json(w.end_cycles)),
        ("points", Json::arr(w.points.iter().map(point_to_json))),
    ])
}

/// Inverse of [`sweep_to_json`], schema-checked.
pub fn sweep_from_json(j: &Json) -> Result<WorkloadSweep> {
    let schema = j
        .expect("schema")?
        .as_u64()
        .ok_or_else(|| anyhow!("sweep `schema` is not an integer"))?;
    ensure!(
        schema == LAB_SCHEMA_VERSION,
        "sweep artifact has schema {schema}, this build reads {LAB_SCHEMA_VERSION}"
    );
    let name = j
        .expect("name")?
        .as_str()
        .ok_or_else(|| anyhow!("sweep `name` is not a string"))?
        .to_string();
    let end_cycles = get_hex(j, "end_cycles")?;
    let points = j
        .expect("points")?
        .as_arr()
        .ok_or_else(|| anyhow!("sweep `points` is not an array"))?
        .iter()
        .map(point_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(WorkloadSweep {
        name,
        end_cycles,
        points,
    })
}

/// Persist a [`crate::api::BatchRunner`] batch into the store: one job
/// per unique spec, keyed directly by the spec content hash (batch jobs
/// are flat — no planner dependencies). Jobs already complete are
/// skipped, so repeated batches are pure cache hits. Returns the ids
/// newly written.
pub fn persist_batch(store: &Store, results: &[crate::api::BatchResult]) -> Result<Vec<u64>> {
    let mut written = Vec::new();
    for r in results {
        if store.is_complete(r.hash) || written.contains(&r.hash) {
            continue;
        }
        store.begin(r.hash)?;
        store.write_artifact(r.hash, "report.txt", r.report().as_bytes())?;
        let manifest = Json::obj(vec![
            ("schema", Json::num(LAB_SCHEMA_VERSION as u32)),
            ("kind", Json::str("batch")),
            ("label", Json::str(format!("batch:{}", hex(r.hash)))),
            ("job", hex_json(r.hash)),
            ("deps", Json::arr(Vec::new())),
            ("spec", r.spec.manifest_json()),
            ("artifacts", Json::arr([Json::str("report.txt")])),
        ]);
        store.finish(r.hash, &manifest)?;
        written.push(r.hash);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> Store {
        let root = std::env::temp_dir()
            .join(format!("trapti-lab-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        Store::new(root)
    }

    fn sample_point(seed: f64) -> SweepPoint {
        // Deliberately awkward floats: codec must round-trip exact bits,
        // not pretty decimals.
        let ch = SramCharacterization {
            capacity: u64::MAX - 3,
            banks: 8,
            e_read_j: 1.0e-12 * seed,
            e_write_j: 1.3e-12 * seed,
            p_leak_bank_w: 0.1 / seed,
            e_switch_j: 2.0e-9,
            wake_cycles: 12,
            area_mm2: 3.07,
            latency_cycles: 2,
        };
        SweepPoint {
            eval: BankingEval {
                capacity: (1 << 62) + 1,
                banks: 8,
                alpha: 0.9,
                policy: GatingPolicy::Conservative {
                    min_idle_factor: 4.0 + seed / 3.0,
                },
                e_dyn_j: 0.1 + seed,
                e_leak_j: std::f64::consts::PI,
                e_sw_j: 1.0 / 3.0,
                n_switch: 9_007_199_254_740_993, // 2^53 + 1: breaks f64 JSON
                avg_active_banks: 5.25,
                gated_fraction: 0.333_333_333_333_333_3,
                area_mm2: 4.2,
                latency_cycles: 3,
                characterization: ch,
            },
            base_e_j: 2.5 * seed,
            base_area_mm2: 3.9,
        }
    }

    #[test]
    fn sweep_codec_round_trips_bit_exact() {
        let w = WorkloadSweep {
            name: "tiny-gqa-decode16+8".into(),
            end_cycles: u64::MAX / 7,
            points: vec![sample_point(1.0), sample_point(2.0)],
        };
        let text = sweep_to_json(&w).to_string_pretty();
        let back = sweep_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, w.name);
        assert_eq!(back.end_cycles, w.end_cycles);
        assert_eq!(back.points.len(), w.points.len());
        for (a, b) in back.points.iter().zip(&w.points) {
            assert_eq!(a.eval.capacity, b.eval.capacity);
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
            assert_eq!(a.eval.alpha.to_bits(), b.eval.alpha.to_bits());
            assert_eq!(a.eval.e_leak_j.to_bits(), b.eval.e_leak_j.to_bits());
            assert_eq!(a.eval.policy, b.eval.policy);
            assert_eq!(
                a.eval.characterization.e_read_j.to_bits(),
                b.eval.characterization.e_read_j.to_bits()
            );
            assert_eq!(a.base_e_j.to_bits(), b.base_e_j.to_bits());
            assert_eq!(
                a.eval.e_total_j().to_bits(),
                b.eval.e_total_j().to_bits()
            );
        }
        // And the serialized form itself is stable (BTreeMap ordering).
        assert_eq!(sweep_to_json(&back).to_string_pretty(), text);
    }

    #[test]
    fn marker_semantics_and_begin_wipe() {
        let store = tmp_store("marker");
        let id = 0xdead_beef_0000_0001;
        assert!(!store.is_complete(id));
        store.begin(id).unwrap();
        store.write_artifact(id, "a.txt", b"hello").unwrap();
        // No marker yet: the job is interrupted, not complete.
        assert!(!store.is_complete(id));
        // begin() wipes interrupted remains.
        store.begin(id).unwrap();
        assert!(!store.artifact_path(id, "a.txt").exists());
        store.write_artifact(id, "a.txt", b"hello").unwrap();
        store
            .finish(id, &Json::obj(vec![("schema", Json::num(1u32))]))
            .unwrap();
        assert!(store.is_complete(id));
        assert_eq!(store.read_artifact(id, "a.txt").unwrap(), b"hello");
        let m = store.manifest(id).unwrap();
        assert_eq!(m.expect("schema").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(store.root());
    }

    #[test]
    fn jobs_listing_and_gc_preserve_live() {
        let store = tmp_store("gc");
        assert!(store.jobs().unwrap().is_empty(), "missing root is empty");
        for id in [3u64, 1, 2] {
            store.begin(id).unwrap();
            store
                .finish(id, &Json::obj(vec![("schema", Json::num(1u32))]))
                .unwrap();
        }
        // Non-id entries under the root are ignored and never touched.
        fs::write(store.root().join("README"), b"not a job").unwrap();
        assert_eq!(store.jobs().unwrap(), vec![1, 2, 3]);
        let live: BTreeSet<u64> = [1u64, 3].into_iter().collect();
        let removed = store.gc(&live).unwrap();
        assert_eq!(removed, vec![2]);
        assert_eq!(store.jobs().unwrap(), vec![1, 3]);
        assert!(store.is_complete(1) && store.is_complete(3));
        assert!(store.root().join("README").is_file());
        let _ = fs::remove_dir_all(store.root());
    }

    #[test]
    fn hex_round_trip_and_rejects() {
        assert_eq!(hex(0), "0000000000000000");
        assert_eq!(parse_hex(&hex(u64::MAX)).unwrap(), u64::MAX);
        assert!(parse_hex("abc").is_err(), "too short");
        assert!(parse_hex("zzzzzzzzzzzzzzzz").is_err(), "not hex");
    }

    #[test]
    fn schema_mismatch_is_rejected() {
        let bad = Json::obj(vec![("schema", Json::num(99u32))]);
        assert!(sweep_from_json(&bad).is_err());
        let store = tmp_store("schema");
        store.begin(7).unwrap();
        store.finish(7, &bad).unwrap();
        assert!(store.manifest(7).is_err());
        let _ = fs::remove_dir_all(store.root());
    }
}
