//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.json` records, per AOT entry, the positional input
//! order with shapes/dtypes and the declared outputs, so the runtime
//! never guesses pytree flattening. Parsed with the in-tree JSON parser.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "int8" => Ok(DType::I8),
            other => bail!("unsupported dtype `{other}`"),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Shape as i64 (what `Literal::reshape` wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model name, kind, ...).
    pub meta_kind: String,
    pub meta_model: Option<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let root = parse(text)?;
        let entries_obj = root
            .expect("entries")?
            .as_obj()
            .ok_or_else(|| anyhow!("`entries` must be an object"))?;
        let mut entries = Vec::new();
        for (name, e) in entries_obj {
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.expect(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("`{key}` must be an array"))?
                    .iter()
                    .map(|io| {
                        let shape = io
                            .expect("shape")?
                            .as_arr()
                            .ok_or_else(|| anyhow!("shape must be array"))?
                            .iter()
                            .map(|d| {
                                d.as_u64()
                                    .map(|v| v as usize)
                                    .ok_or_else(|| anyhow!("bad dim"))
                            })
                            .collect::<Result<Vec<_>>>()?;
                        Ok(TensorSpec {
                            name: io
                                .expect("name")?
                                .as_str()
                                .ok_or_else(|| anyhow!("name must be string"))?
                                .to_string(),
                            shape,
                            dtype: DType::parse(
                                io.expect("dtype")?
                                    .as_str()
                                    .ok_or_else(|| anyhow!("dtype must be string"))?,
                            )?,
                        })
                    })
                    .collect()
            };
            let meta = e.expect("meta")?;
            entries.push(Entry {
                name: name.clone(),
                file: PathBuf::from(
                    e.expect("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("file must be string"))?,
                ),
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
                meta_kind: meta
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                meta_model: meta
                    .get("model")
                    .and_then(Json::as_str)
                    .map(String::from),
            });
        }
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Self {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no artifact entry `{name}`"))
    }

    pub fn hlo_path(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

/// Default artifact directory: `$CARGO_MANIFEST_DIR/artifacts` when run
/// in-tree, else `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    let in_tree = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if in_tree.exists() {
        in_tree
    } else {
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "neg_inf": -1e30,
      "entries": {
        "matmul_f32_128": {
          "file": "matmul_f32_128.hlo.txt",
          "inputs": [
            {"name": "x", "shape": [128, 128], "dtype": "float32"},
            {"name": "w", "shape": [128, 128], "dtype": "float32"}
          ],
          "outputs": [
            {"name": "out", "shape": [128, 128], "dtype": "float32"}
          ],
          "meta": {"kind": "kernel"}
        },
        "decode_tiny_gqa": {
          "file": "decode_tiny_gqa.hlo.txt",
          "inputs": [
            {"name": "x", "shape": [1, 128], "dtype": "float32"},
            {"name": "pos", "shape": [], "dtype": "int32"}
          ],
          "outputs": [
            {"name": "y", "shape": [1, 128], "dtype": "float32"}
          ],
          "meta": {"kind": "decode", "model": "tiny-gqa"}
        }
      }
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("matmul_f32_128").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![128, 128]);
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.inputs[0].elements(), 128 * 128);
        let d = m.entry("decode_tiny_gqa").unwrap();
        assert_eq!(d.meta_model.as_deref(), Some("tiny-gqa"));
        assert_eq!(d.inputs[1].dtype, DType::I32);
        assert_eq!(d.inputs[1].elements(), 1, "scalar counts one element");
    }

    #[test]
    fn unknown_entry_errors() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn real_manifest_when_present() {
        let dir = default_artifact_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entry("decode_tiny_gqa").is_ok());
            assert!(m.entry("decode_tiny_mha").is_ok());
            for e in &m.entries {
                assert!(m.hlo_path(e).exists(), "{} missing", e.name);
            }
        }
    }
}
