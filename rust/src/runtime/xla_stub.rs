//! Offline stand-in for the `xla` crate (PJRT C API bindings).
//!
//! The container this reproduction builds in has no crates.io access and
//! no PJRT plugin, so the functional runtime compiles against this
//! API-compatible stub instead of the real `xla` crate. Every entry
//! point that would touch PJRT returns a descriptive error at runtime;
//! all call sites in [`super::client`] surface that error through their
//! existing `Result` paths, and the AOT tests already skip when
//! `artifacts/manifest.json` is absent (it requires `make artifacts`,
//! which also needs the online toolchain).
//!
//! To wire the real backend back in, add `xla = "0.1"` to
//! `rust/Cargo.toml` and swap the `use super::xla_stub as xla;` alias in
//! `client.rs` for `use xla;` — the surface below mirrors the subset of
//! the crate the runtime consumes (`PjRtClient::cpu`,
//! `HloModuleProto::from_text_file`, `XlaComputation::from_proto`,
//! `compile`, `execute`, `Literal` conversions).

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built with the offline xla stub \
     (see rust/src/runtime/xla_stub.rs for how to enable it)";

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}
