//! Functional runtime: PJRT loading/execution of the AOT artifacts and
//! the host-side decode session driver. Python never runs here.

pub mod client;
pub mod manifest;
pub mod model_exec;
pub mod xla_stub;

pub use client::{Executable, Runtime, Value};
pub use manifest::{default_artifact_dir, DType, Entry, Manifest, TensorSpec};
pub use model_exec::{DecodeSession, TINY_MAX_SEQ};
