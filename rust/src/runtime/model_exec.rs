//! Functional decode driver over the AOT artifacts.
//!
//! Holds model weights and the KV cache host-side and advances the
//! decoder one token at a time through the compiled `decode_tiny_*`
//! artifacts — the "real inference" path the coordinator co-simulates
//! with Stage I. Weights are synthetic (seeded, scaled normals), matching
//! DESIGN.md's substitution for real checkpoints: same code path,
//! deterministic numerics.

use anyhow::{bail, Result};

use crate::util::rng::Rng;
use crate::workload::{ModelPreset, TINY_GQA, TINY_MHA};

use super::client::{Runtime, Value};

/// Host-side state for one auto-regressive decode session.
pub struct DecodeSession {
    pub preset: ModelPreset,
    entry: String,
    max_seq: usize,
    /// Weight tensors in manifest positional order (after x/kc/vc/pos).
    weights: Vec<Value>,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    pos: usize,
}

/// Max sequence length baked into the tiny AOT configs (python
/// compile/model.py TINY_*.max_seq).
pub const TINY_MAX_SEQ: usize = 128;

impl DecodeSession {
    /// Create a session for `model` ("tiny-mha" | "tiny-gqa") with
    /// seeded synthetic weights.
    pub fn new(rt: &mut Runtime, model: &str, seed: u64) -> Result<Self> {
        let preset = match model {
            "tiny-mha" => TINY_MHA,
            "tiny-gqa" => TINY_GQA,
            other => bail!("no decode artifact for model `{other}`"),
        };
        let entry = format!("decode_{}", model.replace('-', "_"));
        let spec = rt.load(&entry)?.entry.clone();
        // Inputs: x, k_cache, v_cache, pos, then weights.
        if spec.inputs.len() < 5 {
            bail!("decode artifact `{entry}` has unexpected signature");
        }
        let mut rng = Rng::new(seed);
        let mut weights = Vec::new();
        for w in &spec.inputs[4..] {
            let mut buf = vec![0f32; w.elements()];
            // Norm scales init to 1, everything else scaled normal.
            if w.name.starts_with("ln") && w.name.ends_with("_g") {
                buf.fill(1.0);
            } else if w.name.starts_with("ln") {
                buf.fill(0.0);
            } else {
                let fan_in = *w.shape.get(w.shape.len() - 2).unwrap_or(&1) as f32;
                rng.fill_normal_f32(&mut buf, 1.0 / fan_in.sqrt());
            }
            weights.push(Value::F32(buf));
        }
        let kv_len = spec.inputs[1].elements();
        Ok(Self {
            preset,
            entry,
            max_seq: TINY_MAX_SEQ,
            weights,
            k_cache: vec![0f32; kv_len],
            v_cache: vec![0f32; kv_len],
            pos: 0,
        })
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    /// Advance one decode step with input hidden state `x` ([d_model]).
    /// Returns the output hidden state.
    pub fn step(&mut self, rt: &mut Runtime, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.preset.d_model as usize {
            bail!(
                "x must have {} elements, got {}",
                self.preset.d_model,
                x.len()
            );
        }
        if self.pos >= self.max_seq {
            bail!("KV cache full ({} tokens)", self.max_seq);
        }
        let mut inputs = vec![
            Value::F32(x.to_vec()),
            Value::F32(std::mem::take(&mut self.k_cache)),
            Value::F32(std::mem::take(&mut self.v_cache)),
            Value::scalar_i32(self.pos as i32),
        ];
        inputs.extend(self.weights.iter().cloned());
        let mut out = rt.execute(&self.entry, &inputs)?;
        // Outputs: y, new_k_cache, new_v_cache.
        let v_new = out.pop().expect("v_cache");
        let k_new = out.pop().expect("k_cache");
        let y = out.pop().expect("y");
        self.k_cache = match k_new {
            Value::F32(v) => v,
            _ => bail!("k_cache must be f32"),
        };
        self.v_cache = match v_new {
            Value::F32(v) => v,
            _ => bail!("v_cache must be f32"),
        };
        self.pos += 1;
        Ok(y.as_f32()?.to_vec())
    }

    /// Auto-regressively generate `n` steps feeding each output back as
    /// the next input (tanh-squashed to keep the synthetic hidden-state
    /// recursion bounded). Returns the mean |y| per step — the driver's
    /// "loss curve" analogue recorded by the e2e example.
    pub fn generate(&mut self, rt: &mut Runtime, n: usize, seed: u64) -> Result<Vec<f32>> {
        let d = self.preset.d_model as usize;
        let mut rng = Rng::new(seed);
        let mut x = vec![0f32; d];
        rng.fill_normal_f32(&mut x, 1.0);
        let mut magnitudes = Vec::with_capacity(n);
        for _ in 0..n {
            let y = self.step(rt, &x)?;
            let mean_abs = y.iter().map(|v| v.abs()).sum::<f32>() / d as f32;
            magnitudes.push(mean_abs);
            if !mean_abs.is_finite() {
                bail!("decode diverged (non-finite activations)");
            }
            for (xi, yi) in x.iter_mut().zip(&y) {
                *xi = yi.tanh();
            }
        }
        Ok(magnitudes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{default_artifact_dir, Manifest};

    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Runtime::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    /// Non-degenerate test input (a constant vector is a LayerNorm
    /// fixed point: norm maps it to the zero vector, so every residual
    /// contribution vanishes and y == x exactly).
    fn varied_x(d: usize) -> Vec<f32> {
        (0..d).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect()
    }

    #[test]
    fn decode_session_steps_both_models() {
        let Some(mut rt) = runtime() else { return };
        for model in ["tiny-mha", "tiny-gqa"] {
            let mut sess = DecodeSession::new(&mut rt, model, 7).unwrap();
            let d = sess.preset.d_model as usize;
            let x = varied_x(d);
            let y1 = sess.step(&mut rt, &x).unwrap();
            assert_eq!(y1.len(), d);
            assert!(y1.iter().all(|v| v.is_finite()));
            assert_ne!(y1, x, "{model}: decode must transform the input");
            // A *different* token at position 1: its attention mixes in
            // token 0's KV, so re-running it later at position 0 would
            // give something else. (Identical tokens would be a fixed
            // point: attention over duplicate KV entries collapses.)
            let x2: Vec<f32> = x.iter().map(|v| -v * 0.5 + 0.1).collect();
            let y2 = sess.step(&mut rt, &x2).unwrap();
            assert_eq!(sess.pos(), 2);
            // Same token replayed in a fresh session at position 0 must
            // differ from its position-1 output (KV influence).
            let mut fresh = DecodeSession::new(&mut rt, model, 7).unwrap();
            let y2_fresh = fresh.step(&mut rt, &x2).unwrap();
            assert_ne!(y2, y2_fresh, "{model}: KV cache must influence step 2");
        }
    }

    #[test]
    fn layernorm_fixed_point_sanity() {
        // Documents the degenerate case above: constant input through a
        // LayerNorm model is a fixed point of the whole block.
        let Some(mut rt) = runtime() else { return };
        let mut sess = DecodeSession::new(&mut rt, "tiny-mha", 7).unwrap();
        let d = sess.preset.d_model as usize;
        let y = sess.step(&mut rt, &vec![0.5; d]).unwrap();
        assert_eq!(y, vec![0.5; d]);
    }

    #[test]
    fn decode_deterministic_across_sessions() {
        let Some(mut rt) = runtime() else { return };
        let d = TINY_GQA.d_model as usize;
        let x = varied_x(d);
        let mut a = DecodeSession::new(&mut rt, "tiny-gqa", 42).unwrap();
        let ya = a.step(&mut rt, &x).unwrap();
        let mut b = DecodeSession::new(&mut rt, "tiny-gqa", 42).unwrap();
        let yb = b.step(&mut rt, &x).unwrap();
        assert_eq!(ya, yb);
    }

    #[test]
    fn generate_stays_finite() {
        let Some(mut rt) = runtime() else { return };
        let mut sess = DecodeSession::new(&mut rt, "tiny-gqa", 3).unwrap();
        let mags = sess.generate(&mut rt, 8, 11).unwrap();
        assert_eq!(mags.len(), 8);
        assert!(mags.iter().all(|m| m.is_finite() && *m > 0.0));
    }

    #[test]
    fn matches_prefill_artifact() {
        // The decisive cross-layer check: sequential decode through the
        // decode artifact == batched prefill artifact on the same
        // weights (both lowered from the same L2 model + L1 kernels).
        let Some(mut rt) = runtime() else { return };
        let m = 32usize; // prefill artifact was lowered at m=32
        let mut sess = DecodeSession::new(&mut rt, "tiny-gqa", 123).unwrap();
        let d = sess.preset.d_model as usize;

        // Deterministic prompt hidden states.
        let mut rng = crate::util::rng::Rng::new(5);
        let mut xs = vec![0f32; m * d];
        rng.fill_normal_f32(&mut xs, 1.0);

        // Prefill path.
        let mut inputs = vec![Value::F32(xs.clone())];
        inputs.extend(sess.weights.iter().cloned());
        let pre = rt.execute("prefill_tiny_gqa", &inputs).unwrap();
        let ys_pre = pre[0].as_f32().unwrap().to_vec();

        // Decode path, token by token.
        let mut ys_dec = Vec::new();
        for t in 0..m {
            let y = sess.step(&mut rt, &xs[t * d..(t + 1) * d]).unwrap();
            ys_dec.extend(y);
        }
        let max_err = ys_pre
            .iter()
            .zip(&ys_dec)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 2e-3, "prefill vs decode divergence: {max_err}");
    }

    #[test]
    fn cache_overflow_rejected() {
        let Some(mut rt) = runtime() else { return };
        let mut sess = DecodeSession::new(&mut rt, "tiny-mha", 1).unwrap();
        sess.pos = TINY_MAX_SEQ;
        let d = sess.preset.d_model as usize;
        assert!(sess.step(&mut rt, &vec![0.0; d]).is_err());
    }
}
