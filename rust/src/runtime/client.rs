//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! HLO *text* is the interchange format (jax >= 0.5 serialized protos are
//! rejected by xla_extension 0.5.1 — see aot.py / DESIGN.md).
//!
//! Python never runs here: artifacts are self-contained after
//! `make artifacts`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

// Offline builds link the API-compatible stub; swap for the real `xla`
// crate to enable PJRT execution (see xla_stub.rs module docs).
use super::manifest::{DType, Entry, Manifest};
use super::xla_stub as xla;

/// A host-side tensor value passed to / returned from an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(vec![v])
    }
}

/// One compiled artifact.
pub struct Executable {
    pub entry: Entry,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// CPU PJRT client over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            self.cache.insert(name.to_string(), Executable { entry, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact with positional inputs (manifest order).
    /// Returns outputs in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.load(name)?;
        let exe = &self.cache[name];
        let entry = &exe.entry;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "artifact `{name}` expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (val, spec) in inputs.iter().zip(&entry.inputs) {
            if val.len() != spec.elements() {
                bail!(
                    "input `{}` of `{name}`: expected {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    val.len()
                );
            }
            let lit = match (val, &spec.dtype) {
                (Value::F32(v), DType::F32) => {
                    let l = xla::Literal::vec1(v);
                    if spec.shape.is_empty() {
                        l.reshape(&[])?
                    } else {
                        l.reshape(&spec.dims_i64())?
                    }
                }
                (Value::I32(v), DType::I32) => {
                    let l = xla::Literal::vec1(v);
                    if spec.shape.is_empty() {
                        l.reshape(&[])?
                    } else {
                        l.reshape(&spec.dims_i64())?
                    }
                }
                (v, d) => bail!(
                    "input `{}` of `{name}`: value/dtype mismatch ({:?} vs {:?})",
                    spec.name,
                    std::mem::discriminant(v),
                    d
                ),
            };
            literals.push(lit);
        }
        let result = exe.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "artifact `{name}` returned {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&entry.outputs) {
            let v = match spec.dtype {
                DType::F32 => Value::F32(lit.to_vec::<f32>()?),
                DType::I32 => Value::I32(lit.to_vec::<i32>()?),
                DType::I8 => bail!("i8 outputs not supported"),
            };
            if v.len() != spec.elements() {
                bail!(
                    "output `{}` of `{name}`: expected {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    v.len()
                );
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_artifact_dir;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: run `make artifacts`");
            return None;
        }
        Some(Runtime::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn matmul_artifact_numerics() {
        let Some(mut rt) = runtime() else { return };
        // x = I (128), w = counting matrix: out == w.
        let n = 128usize;
        let mut x = vec![0f32; n * n];
        for i in 0..n {
            x[i * n + i] = 1.0;
        }
        let w: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
        let out = rt
            .execute("matmul_f32_128", &[Value::F32(x), Value::F32(w.clone())])
            .unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();
        for (a, b) in got.iter().zip(&w) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn attention_artifact_masks_padding() {
        let Some(mut rt) = runtime() else { return };
        let (h, hkv, dh, s) = (4usize, 2usize, 32usize, 128usize);
        let q = vec![0.1f32; h * dh];
        let k: Vec<f32> = (0..s * hkv * dh).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut v = vec![0f32; s * hkv * dh];
        // Valid region: constant 2.0; padded region: garbage.
        for t in 0..s {
            for j in 0..hkv * dh {
                v[t * hkv * dh + j] = if t < 10 { 2.0 } else { 1e6 };
            }
        }
        let mask: Vec<f32> = (0..s)
            .map(|t| if t < 10 { 0.0 } else { -1e30 })
            .collect();
        let out = rt
            .execute(
                "attn_decode_gqa",
                &[Value::F32(q), Value::F32(k), Value::F32(v), Value::F32(mask)],
            )
            .unwrap();
        let got = out[0].as_f32().unwrap();
        // Convex combination of constant-2.0 values == 2.0 everywhere.
        for x in got {
            assert!((x - 2.0).abs() < 1e-3, "{x}");
        }
    }

    #[test]
    fn input_arity_checked() {
        let Some(mut rt) = runtime() else { return };
        let err = rt.execute("matmul_f32_128", &[]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn input_shape_checked() {
        let Some(mut rt) = runtime() else { return };
        let err = rt
            .execute(
                "matmul_f32_128",
                &[Value::F32(vec![0.0; 3]), Value::F32(vec![0.0; 128 * 128])],
            )
            .unwrap_err();
        assert!(err.to_string().contains("elements"));
    }
}
