//! `ExperimentSpec`: the validated, hashable description of one TRAPTI
//! scenario (model × workload × accelerator × optional Stage-II grid).
//!
//! A spec is pure data — building one runs nothing. `run_stage1` (see
//! [`super::stage`]) turns it into results; [`super::BatchRunner`]
//! executes many concurrently, memoized by [`ExperimentSpec::content_hash`].

use anyhow::{bail, ensure, Result};

use crate::banking::{GatingPolicy, HierarchyConfig, SweepSpec};
use crate::config::{baseline, AccelConfig};
use crate::serving::ServingParams;
use crate::util::fnv::Fnv64 as Fnv;
use crate::util::json::Json;
use crate::workload::{FfnKind, ModelPreset, NormKind, Workload};

/// One fully-specified experiment. Construct via [`ExperimentSpec::builder`].
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub model: ModelPreset,
    pub workload: Workload,
    pub accel: AccelConfig,
    /// Stage-II sweep grid. `None` means "derive the paper grid from the
    /// Stage-I peak" when Stage II is requested.
    pub sweep: Option<SweepSpec>,
    /// Hierarchy-aware Stage II/III: banked L1 backed by an L2 spill
    /// pool (see [`crate::banking::hierarchy`]). `None` (the default)
    /// keeps the flat single-SRAM sweep and does not join the hash.
    pub hierarchy: Option<HierarchyConfig>,
}

impl ExperimentSpec {
    pub fn builder() -> ExperimentSpecBuilder {
        ExperimentSpecBuilder::default()
    }

    /// Frequency used for Stage-II cycle→seconds conversion.
    pub fn freq_ghz(&self) -> f64 {
        self.accel.sa.freq_ghz
    }

    /// Stable 64-bit content hash (FNV-1a over a canonical field
    /// serialization). Two specs hash equal iff every semantic field is
    /// equal — builder call order cannot matter because the hash is
    /// computed on the built value. Used as the `BatchRunner`
    /// memoization key.
    pub fn content_hash(&self) -> u64 {
        let mut h = Fnv::new();
        h.str("trapti-spec-v1");

        // Model (full structural fields, so custom presets hash too).
        h.str(self.model.name);
        h.u64(self.model.layers as u64);
        h.u64(self.model.d_model as u64);
        h.u64(self.model.heads as u64);
        h.u64(self.model.kv_heads as u64);
        h.u64(self.model.d_head as u64);
        h.u64(self.model.d_ff as u64);
        h.u64(match self.model.ffn {
            FfnKind::Gelu => 0,
            FfnKind::SwiGlu => 1,
        });
        h.u64(match self.model.norm {
            NormKind::LayerNorm => 0,
            NormKind::RmsNorm => 1,
        });
        // Spec-hash extension rule (same as the serving gate below):
        // attention-variant fields join the hash only when enabled, so
        // every pre-spectrum preset keeps its exact original pin.
        if self.model.has_attn_extensions() {
            h.u64(0x4d1a_77a1);
            h.u64(self.model.latent_dim as u64);
            h.u64(self.model.window as u64);
        }

        // Workload.
        match self.workload {
            Workload::Prefill { seq } => {
                h.u64(0);
                h.u64(seq as u64);
            }
            Workload::Decode { prompt, gen } => {
                h.u64(1);
                h.u64(prompt as u64);
                h.u64(gen as u64);
            }
            Workload::Serving(p) => {
                h.u64(2);
                h.u64(p.requests as u64);
                h.u64(p.concurrency as u64);
                h.u64(p.seed);
                h.u64(p.mean_arrival_gap);
                h.u64(p.prompt_min as u64);
                h.u64(p.prompt_max as u64);
                h.u64(p.gen_min as u64);
                h.u64(p.gen_max as u64);
                h.u64(p.page_tokens as u64);
                // Spec-hash extension rule: traffic/scheduling extensions
                // join the hash only when at least one is enabled (marker
                // word first, so an extended spec can never collide with a
                // legacy spec whose trailing fields happen to match).
                // Pre-extension serving specs therefore keep their exact
                // original hashes — pinned in `tests/spec_hash_pin.rs`.
                if p.has_extensions() {
                    h.u64(0x5f37_59df);
                    h.u64(p.burst_gap);
                    h.u64(p.burst_len as u64);
                    h.u64(p.calm_len as u64);
                    h.u64(p.len_tail_q8 as u64);
                    h.u64(p.tiers as u64);
                    h.u64(p.prefix_tokens as u64);
                    h.u64(p.tenants as u64);
                }
            }
        }

        // Accelerator.
        h.str(&self.accel.name);
        h.u64(self.accel.sa.rows as u64);
        h.u64(self.accel.sa.cols as u64);
        h.u64(self.accel.sa.count as u64);
        h.f64(self.accel.sa.freq_ghz);
        h.u64(self.accel.fifo.lanes as u64);
        h.u64(self.accel.fifo.depth as u64);
        h.u64(self.accel.on_chip.len() as u64);
        for m in self.accel.on_chip.iter().chain(std::iter::once(&self.accel.dram)) {
            h.str(&m.name);
            h.u64(m.capacity);
            h.u64(m.ports as u64);
            h.u64(m.bytes_per_cycle as u64);
            h.u64(m.latency_cycles);
        }
        h.u64(self.accel.sched.subops as u64);
        h.u64(self.accel.sched.issue_window as u64);
        h.u64(self.accel.sched.window_stages as u64);
        h.u64(self.accel.sched.weight_prefetch_ops as u64);
        h.u64(self.accel.sched.mem_path_bytes_per_cycle as u64);
        h.u64(self.accel.sched.weight_resident as u64);
        h.u64(self.accel.topology.mem_of_sa.len() as u64);
        for &m in &self.accel.topology.mem_of_sa {
            h.u64(m as u64);
        }

        // Sweep.
        match &self.sweep {
            None => h.u64(0),
            Some(s) => {
                h.u64(1);
                h.u64(s.capacities.len() as u64);
                for &c in &s.capacities {
                    h.u64(c);
                }
                h.u64(s.banks.len() as u64);
                for &b in &s.banks {
                    h.u64(b as u64);
                }
                h.u64(s.alphas.len() as u64);
                for &a in &s.alphas {
                    h.f64(a);
                }
                h.u64(s.policies.len() as u64);
                for p in &s.policies {
                    hash_policy(&mut h, p);
                }
            }
        }

        // Hierarchy (default-off; extension rule again — a flat spec
        // keeps its pre-hierarchy hash bit-for-bit).
        if let Some(hc) = &self.hierarchy {
            h.u64(0x4c32_5350);
            h.u64(hc.l2_capacity);
            h.f64(hc.migrate_energy_per_byte_j);
        }
        h.finish()
    }

    /// Human-auditable provenance record of this spec for lab store
    /// manifests (`result/<job-id>/manifest.json`). Every `u64` is
    /// emitted as a decimal string — `Json::Num` is an `f64` and would
    /// silently round capacities above 2^53.
    pub fn manifest_json(&self) -> Json {
        let u = |v: u64| Json::str(v.to_string());
        let mut model_fields = vec![
            ("name", Json::str(self.model.name)),
            ("layers", Json::num(self.model.layers)),
            ("d_model", Json::num(self.model.d_model)),
            ("heads", Json::num(self.model.heads)),
            ("kv_heads", Json::num(self.model.kv_heads)),
            ("d_head", Json::num(self.model.d_head)),
            ("d_ff", Json::num(self.model.d_ff)),
            ("ffn", Json::str(format!("{:?}", self.model.ffn))),
            ("norm", Json::str(format!("{:?}", self.model.norm))),
        ];
        // Mirrors the hash's attention-extension rule: pre-spectrum
        // manifests stay byte-identical.
        if self.model.has_attn_extensions() {
            model_fields.push(("latent_dim", Json::num(self.model.latent_dim)));
            model_fields.push(("window", Json::num(self.model.window)));
        }
        let model = Json::obj(model_fields);
        let workload = match self.workload {
            Workload::Prefill { seq } => Json::obj(vec![
                ("kind", Json::str("prefill")),
                ("seq", Json::num(seq)),
            ]),
            Workload::Decode { prompt, gen } => Json::obj(vec![
                ("kind", Json::str("decode")),
                ("prompt", Json::num(prompt)),
                ("gen", Json::num(gen)),
            ]),
            Workload::Serving(p) => {
                let mut fields = vec![
                    ("kind", Json::str("serving")),
                    ("requests", Json::num(p.requests)),
                    ("concurrency", Json::num(p.concurrency)),
                    ("seed", u(p.seed)),
                    ("mean_arrival_gap", u(p.mean_arrival_gap)),
                    ("prompt_min", Json::num(p.prompt_min)),
                    ("prompt_max", Json::num(p.prompt_max)),
                    ("gen_min", Json::num(p.gen_min)),
                    ("gen_max", Json::num(p.gen_max)),
                    ("page_tokens", Json::num(p.page_tokens)),
                ];
                // Mirrors the hash's extension rule: legacy manifests
                // stay byte-identical, extended specs are fully recorded.
                if p.has_extensions() {
                    fields.push(("burst_gap", u(p.burst_gap)));
                    fields.push(("burst_len", Json::num(p.burst_len)));
                    fields.push(("calm_len", Json::num(p.calm_len)));
                    fields.push(("len_tail_q8", Json::num(p.len_tail_q8)));
                    fields.push(("tiers", Json::num(p.tiers)));
                    fields.push(("prefix_tokens", Json::num(p.prefix_tokens)));
                    fields.push(("tenants", Json::num(p.tenants)));
                }
                Json::obj(fields)
            }
        };
        let accel = Json::obj(vec![
            ("name", Json::str(self.accel.name.clone())),
            (
                "on_chip_capacity",
                Json::arr(self.accel.on_chip.iter().map(|m| u(m.capacity))),
            ),
            ("freq_ghz", Json::num(self.accel.sa.freq_ghz)),
        ]);
        let sweep = match &self.sweep {
            None => Json::Null,
            Some(s) => Json::obj(vec![
                ("capacities", Json::arr(s.capacities.iter().map(|&c| u(c)))),
                ("banks", Json::arr(s.banks.iter().map(|&b| Json::num(b)))),
                ("alphas", Json::arr(s.alphas.iter().map(|&a| Json::num(a)))),
                (
                    "policies",
                    Json::arr(s.policies.iter().map(|p| Json::str(p.label()))),
                ),
            ]),
        };
        let mut fields = vec![
            ("spec_hash", Json::str(format!("{:016x}", self.content_hash()))),
            ("model", model),
            ("workload", workload),
            ("accel", accel),
            ("sweep", sweep),
        ];
        // Same extension rule: the key only appears when hierarchy is on.
        if let Some(hc) = &self.hierarchy {
            fields.push((
                "hierarchy",
                Json::obj(vec![
                    ("l2_capacity", u(hc.l2_capacity)),
                    ("migrate_energy_per_byte_j", Json::num(hc.migrate_energy_per_byte_j)),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Validate every field; called by the builder and by `BatchRunner`
    /// on externally-constructed specs.
    pub fn validate(&self) -> Result<()> {
        let m = &self.model;
        ensure!(m.layers >= 1, "model `{}` has zero layers", m.name);
        ensure!(
            m.d_model >= 1 && m.d_ff >= 1 && m.d_head >= 1,
            "model `{}` has a zero dimension",
            m.name
        );
        ensure!(
            m.heads >= 1 && m.kv_heads >= 1,
            "model `{}` has zero heads",
            m.name
        );
        ensure!(
            m.heads % m.kv_heads == 0,
            "model `{}`: heads ({}) must be divisible by kv_heads ({})",
            m.name,
            m.heads,
            m.kv_heads
        );
        if m.latent_dim > 0 {
            // Latent KV is a *compression*: the per-token latent must not
            // exceed the uncompressed per-token KV it replaces.
            ensure!(
                m.latent_dim as u64 <= 2 * (m.kv_heads * m.d_head) as u64,
                "model `{}`: latent_dim ({}) exceeds the uncompressed \
                 per-token KV bytes ({})",
                m.name,
                m.latent_dim,
                2 * (m.kv_heads * m.d_head) as u64
            );
        }
        match self.workload {
            Workload::Prefill { seq } => {
                ensure!(seq >= 1, "prefill needs seq >= 1 (got {seq})");
            }
            Workload::Decode { gen, .. } => {
                ensure!(gen >= 1, "decode needs gen >= 1 (got {gen})");
            }
            Workload::Serving(p) => {
                p.validate()?;
                ensure!(
                    p.tenants <= 1
                        || crate::workload::paper_counterpart(m.name).is_some(),
                    "model `{}` has no paper counterpart for multi-model \
                     tenancy (tenants={})",
                    m.name,
                    p.tenants
                );
            }
        }
        self.accel.validate()?;
        if let Some(s) = &self.sweep {
            validate_sweep(s)?;
        }
        if let Some(hc) = &self.hierarchy {
            ensure!(
                hc.l2_capacity >= 1,
                "hierarchy: l2_capacity must be >= 1 byte"
            );
            ensure!(
                hc.migrate_energy_per_byte_j.is_finite()
                    && hc.migrate_energy_per_byte_j >= 0.0,
                "hierarchy: migrate_energy_per_byte_j must be finite and >= 0 \
                 (got {})",
                hc.migrate_energy_per_byte_j
            );
            ensure!(
                !matches!(self.workload, Workload::Serving(_)),
                "hierarchy-aware sweeps need a materializable single-run \
                 trace; serving workloads are not supported"
            );
        }
        Ok(())
    }
}

/// Reject sweep grids the Stage-II evaluator cannot process (empty axes
/// would silently produce zero points; non-power-of-two bank counts
/// would panic inside the CACTI characterization).
pub fn validate_sweep(s: &SweepSpec) -> Result<()> {
    ensure!(!s.capacities.is_empty(), "sweep grid has no capacities");
    ensure!(!s.banks.is_empty(), "sweep grid has no bank counts");
    ensure!(!s.alphas.is_empty(), "sweep grid has no alphas");
    ensure!(!s.policies.is_empty(), "sweep grid has no gating policies");
    for &c in &s.capacities {
        ensure!(c > 0, "sweep capacity must be > 0");
    }
    for &b in &s.banks {
        ensure!(
            b >= 1 && b.is_power_of_two(),
            "bank count {b} must be a power of two >= 1 (CACTI constraint)"
        );
    }
    for &a in &s.alphas {
        ensure!(
            a > 0.0 && a <= 1.0,
            "alpha {a} must be in (0, 1]"
        );
    }
    Ok(())
}

fn hash_policy(h: &mut Fnv, p: &GatingPolicy) {
    match *p {
        GatingPolicy::None => h.u64(0),
        GatingPolicy::Aggressive => h.u64(1),
        GatingPolicy::Conservative { min_idle_factor } => {
            h.u64(2);
            h.f64(min_idle_factor);
        }
        GatingPolicy::Drowsy { retention_factor } => {
            h.u64(3);
            h.f64(retention_factor);
        }
    }
}

/// Builder for [`ExperimentSpec`]; `build()` validates.
#[derive(Debug, Clone, Default)]
pub struct ExperimentSpecBuilder {
    model: Option<ModelPreset>,
    workload: Option<Workload>,
    accel: Option<AccelConfig>,
    sweep: Option<SweepSpec>,
    hierarchy: Option<HierarchyConfig>,
}

impl ExperimentSpecBuilder {
    pub fn model(mut self, model: ModelPreset) -> Self {
        self.model = Some(model);
        self
    }

    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self
    }

    /// Shorthand for `.workload(Workload::Prefill { seq })`.
    pub fn prefill(self, seq: u32) -> Self {
        self.workload(Workload::Prefill { seq })
    }

    /// Shorthand for `.workload(Workload::Decode { prompt, gen })`.
    pub fn decode(self, prompt: u32, gen: u32) -> Self {
        self.workload(Workload::Decode { prompt, gen })
    }

    /// Shorthand for `.workload(Workload::Serving(params))` — a
    /// multi-tenant serving scenario (see [`crate::serving`]). Run it
    /// with `ExperimentSpec::run_serving`, not `run_stage1`.
    pub fn serving(self, params: ServingParams) -> Self {
        self.workload(Workload::Serving(params))
    }

    /// Accelerator configuration; defaults to the paper baseline
    /// (`config::baseline()`) when omitted.
    pub fn accel(mut self, accel: AccelConfig) -> Self {
        self.accel = Some(accel);
        self
    }

    /// Stage-II sweep grid. Omit to derive the paper grid from the
    /// Stage-I peak at Stage-II time.
    pub fn sweep(mut self, sweep: SweepSpec) -> Self {
        self.sweep = Some(sweep);
        self
    }

    /// Enable hierarchy-aware Stage II/III (banked L1 + L2 spill).
    /// Omit for the flat single-SRAM sweep — the default, and the only
    /// mode that keeps pre-hierarchy spec hashes.
    pub fn hierarchy(mut self, config: HierarchyConfig) -> Self {
        self.hierarchy = Some(config);
        self
    }

    pub fn build(self) -> Result<ExperimentSpec> {
        let Some(model) = self.model else {
            bail!("ExperimentSpec: model not set");
        };
        let Some(workload) = self.workload else {
            bail!("ExperimentSpec: workload not set (use .prefill/.decode)");
        };
        let spec = ExperimentSpec {
            model,
            workload,
            accel: self.accel.unwrap_or_else(baseline),
            sweep: self.sweep,
            hierarchy: self.hierarchy,
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::util::MIB;
    use crate::workload::TINY_GQA;

    fn base() -> ExperimentSpec {
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap()
    }

    #[test]
    fn builder_defaults_accel_to_baseline() {
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .build()
            .unwrap();
        assert_eq!(spec.accel.name, "baseline-128MiB");
    }

    #[test]
    fn builder_rejects_missing_fields() {
        assert!(ExperimentSpec::builder().prefill(64).build().is_err());
        assert!(ExperimentSpec::builder().model(TINY_GQA).build().is_err());
    }

    #[test]
    fn builder_rejects_zero_seq_and_gen() {
        let err = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("seq >= 1"), "{err}");
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .decode(16, 0)
            .build()
            .is_err());
    }

    #[test]
    fn builder_rejects_empty_and_invalid_sweep_grids() {
        let empty_banks = SweepSpec {
            capacities: vec![4 * MIB],
            banks: vec![],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .sweep(empty_banks)
            .build()
            .is_err());

        let bad_banks = SweepSpec {
            capacities: vec![4 * MIB],
            banks: vec![3],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .sweep(bad_banks)
            .build()
            .is_err());

        let bad_alpha = SweepSpec {
            capacities: vec![4 * MIB],
            banks: vec![4],
            alphas: vec![1.5],
            policies: vec![GatingPolicy::Aggressive],
        };
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .sweep(bad_alpha)
            .build()
            .is_err());
    }

    #[test]
    fn builder_rejects_indivisible_heads() {
        let mut m = TINY_GQA.clone();
        m.kv_heads = 3; // 4 % 3 != 0
        assert!(ExperimentSpec::builder().model(m).prefill(64).build().is_err());
    }

    #[test]
    fn hash_stable_across_builder_field_order() {
        let a = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap();
        let b = ExperimentSpec::builder()
            .accel(tiny())
            .prefill(64)
            .model(TINY_GQA)
            .build()
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn hash_distinguishes_semantic_changes() {
        let a = base();
        let mut b = base();
        b.workload = Workload::Prefill { seq: 65 };
        assert_ne!(a.content_hash(), b.content_hash());

        let mut c = base();
        c.accel.on_chip[0].capacity += 1;
        assert_ne!(a.content_hash(), c.content_hash());

        let mut d = base();
        d.sweep = Some(SweepSpec::paper_grid(32 * MIB));
        assert_ne!(a.content_hash(), d.content_hash());
    }

    #[test]
    fn hash_is_deterministic_across_clones() {
        let a = base();
        let b = a.clone();
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn serving_spec_builds_and_hashes_stably() {
        let p = ServingParams::new(64, 8, 7);
        let a = ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap();
        let b = a.clone();
        assert_eq!(a.content_hash(), b.content_hash());
        // Every serving field is semantic.
        let edits: [fn(&mut ServingParams); 6] = [
            |p| p.requests += 1,
            |p| p.concurrency += 1,
            |p| p.seed += 1,
            |p| p.mean_arrival_gap += 1,
            |p| p.gen_max += 1,
            |p| p.page_tokens += 1,
        ];
        for (i, f) in edits.into_iter().enumerate() {
            let mut q = p;
            f(&mut q);
            let c = ExperimentSpec::builder()
                .model(TINY_GQA)
                .serving(q)
                .accel(tiny())
                .build()
                .unwrap();
            assert_ne!(a.content_hash(), c.content_hash(), "field {i}");
        }
    }

    #[test]
    fn serving_extension_fields_are_semantic() {
        let p = ServingParams::new(64, 8, 7);
        let spec_of = |q: ServingParams| {
            ExperimentSpec::builder()
                .model(TINY_GQA)
                .serving(q)
                .accel(tiny())
                .build()
                .unwrap()
        };
        let base = spec_of(p);
        let edits: [fn(&mut ServingParams); 5] = [
            |p| *p = p.with_bursty_traffic(),
            |p| p.len_tail_q8 = 64,
            |p| p.tiers = 2,
            |p| p.prefix_tokens = 8,
            |p| p.tenants = 2,
        ];
        for (i, f) in edits.into_iter().enumerate() {
            let mut q = p;
            f(&mut q);
            assert_ne!(
                base.content_hash(),
                spec_of(q).content_hash(),
                "extension edit {i} must change the hash"
            );
        }
        // Legacy manifests carry no extension fields; extended ones do.
        let legacy = base.manifest_json().to_string_compact();
        assert!(!legacy.contains("burst_gap"), "{legacy}");
        let extended = spec_of(p.with_bursty_traffic())
            .manifest_json()
            .to_string_compact();
        assert!(extended.contains("burst_gap"), "{extended}");
        assert!(extended.contains("tenants"), "{extended}");
    }

    #[test]
    fn attn_extension_fields_are_semantic_and_gated() {
        let flat = base();
        let mut mla = base();
        mla.model.latent_dim = 16;
        assert_ne!(flat.content_hash(), mla.content_hash());
        let mut win = base();
        win.model.window = 32;
        assert_ne!(flat.content_hash(), win.content_hash());
        assert_ne!(mla.content_hash(), win.content_hash());
        // Manifests mirror the gate: legacy stays byte-identical.
        let legacy = flat.manifest_json().to_string_compact();
        assert!(!legacy.contains("latent_dim"), "{legacy}");
        let extended = mla.manifest_json().to_string_compact();
        assert!(extended.contains("latent_dim"), "{extended}");
        assert!(extended.contains("window"), "{extended}");
    }

    #[test]
    fn hierarchy_is_default_off_and_semantic() {
        let flat = base();
        let mut h = base();
        h.hierarchy = Some(HierarchyConfig::new(8 * MIB));
        assert_ne!(flat.content_hash(), h.content_hash());
        let mut h2 = base();
        h2.hierarchy = Some(HierarchyConfig {
            l2_capacity: 8 * MIB,
            migrate_energy_per_byte_j: 1e-12,
        });
        assert_ne!(h.content_hash(), h2.content_hash());
        assert!(!flat.manifest_json().to_string_compact().contains("hierarchy"));
        assert!(h.manifest_json().to_string_compact().contains("l2_capacity"));
    }

    #[test]
    fn builder_rejects_bad_latent_and_serving_hierarchy() {
        let mut m = TINY_GQA.clone();
        m.latent_dim = 1 << 20; // far above 2 * kv_heads * d_head
        assert!(ExperimentSpec::builder().model(m).prefill(64).build().is_err());

        let err = ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(ServingParams::new(8, 2, 7))
            .accel(tiny())
            .hierarchy(HierarchyConfig::new(8 * MIB))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("serving"), "{err}");
    }

    #[test]
    fn builder_rejects_tenancy_without_counterpart() {
        let mut m = TINY_GQA.clone();
        m.name = "mystery-model";
        let mut p = ServingParams::new(8, 2, 7);
        p.tenants = 2;
        let err = ExperimentSpec::builder()
            .model(m)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("no paper counterpart"), "{err}");
        // The paired preset builds fine.
        p.tenants = 2;
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .is_ok());
    }

    #[test]
    fn builder_rejects_invalid_serving_params() {
        let mut p = ServingParams::new(0, 8, 7);
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .build()
            .is_err());
        p = ServingParams::new(8, 8, 7);
        p.gen_min = 0;
        assert!(ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .build()
            .is_err());
    }
}
