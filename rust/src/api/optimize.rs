//! `api` surface of the Stage-II Pareto/portfolio optimizer
//! ([`crate::banking::optimize`](mod@crate::banking::optimize)).
//!
//! Three entry points:
//!
//! * [`Stage2Run::optimize`] — frontier (+ trivial single-workload
//!   portfolio) over an existing single-sequence Stage-II run.
//! * [`ServingSweep::optimize`] — the same over a serving sweep.
//! * [`run_portfolio`] — the batch entry point: execute several
//!   [`ExperimentSpec`]s (mixed single-sequence and serving), collect
//!   one [`WorkloadSweep`] each, and run the cross-workload optimizer.
//!   Whenever a shared explicit grid is available, Stage I streams
//!   straight into the fused [`crate::banking::SweepSink`]
//!   (`stream_stage2` / `serve_fused_with`), so serving-scale grids
//!   reach the optimizer **without materializing a trace**.
//!
//! Everything downstream of the simulations is deterministic; two
//! `run_portfolio` calls over equal specs produce identical results
//! (the CI gate compares `repro optimize --pareto-csv` bytes).

use anyhow::{ensure, Result};

use crate::banking::online::OnlineConfig;
use crate::banking::optimize::{
    optimize, ConfigKey, Constraints, FrontierPoint, OptimizeResult,
    WorkloadFrontier, WorkloadSweep,
};
use crate::banking::SweepSpec;
use crate::cacti::CactiModel;
use crate::trace::{AccessStats, OccupancyTrace};
use crate::workload::Workload;

use super::serving::ServingSweep;
use super::spec::ExperimentSpec;
use super::stage::{ApiContext, Stage2Run};

/// Options for [`run_portfolio`].
#[derive(Debug, Clone, Default)]
pub struct PortfolioOptions {
    /// Shared Stage-II grid for every workload. `None` falls back to
    /// each spec's own grid (`ExperimentSpec::sweep`), then to the
    /// derived default (arena grid for serving, peak-derived paper grid
    /// for single-sequence — the latter forces a materialized run). A
    /// portfolio needs overlapping grids to find shared configurations,
    /// so passing one shared grid here is the robust choice.
    pub grid: Option<SweepSpec>,
    pub constraints: Constraints,
    /// ε for the per-workload frontiers (0 = exact).
    pub epsilon: f64,
    /// Per-workload weights for the mean-regret tie-breaker.
    pub weights: Option<Vec<f64>>,
}

/// A portfolio run's collected inputs and optimizer output.
#[derive(Debug, Clone)]
pub struct PortfolioRun {
    pub workloads: Vec<WorkloadSweep>,
    pub result: OptimizeResult,
}

/// Closed-form capacity upper bound covering `spec`'s occupancy without
/// running a simulation: the provisioned KV-arena bound for serving
/// ([`crate::sim::serving::arena_capacity`]), 2x the KV footprint for
/// single-sequence shapes, rounded up to a 16 MiB step. The single
/// source of truth for every derived covering grid (CLI default, bench,
/// CI gate) so the rounding/bound formula cannot drift between them.
pub fn covering_capacity_bound(spec: &ExperimentSpec) -> u64 {
    use crate::sim::serving::arena_capacity;
    use crate::util::MIB;
    let bound = match spec.workload {
        Workload::Serving(p) => arena_capacity(&spec.model, &p),
        Workload::Prefill { seq } => spec.model.kv_cache_bytes(seq as u64) * 2,
        Workload::Decode { prompt, gen } => {
            spec.model.kv_cache_bytes(prompt as u64 + gen as u64) * 2
        }
    };
    bound.div_ceil(16 * MIB).max(1) * 16 * MIB
}

/// The optimizer's full policy axis — the spread from "do nothing" to
/// aggressive gating. One definition shared by [`covering_grid`] and
/// the CLI's explicit-grid flags, so the two `repro optimize` modes can
/// never explore different policy sets.
pub fn full_policy_axis() -> Vec<crate::banking::GatingPolicy> {
    use crate::banking::GatingPolicy;
    vec![
        GatingPolicy::None,
        GatingPolicy::Aggressive,
        GatingPolicy::conservative(),
        GatingPolicy::drowsy(),
    ]
}

/// Shared default grid for [`run_portfolio`]: 16 MiB capacity steps up
/// to the largest covering bound of `specs` (floored at 128 MiB), the
/// paper bank set, α = 0.9, all four gating policies. Purely
/// closed-form — no simulation runs to derive it, so the fused
/// streaming path stays available and the portfolio intersection is
/// never empty.
pub fn covering_grid(specs: &[ExperimentSpec]) -> SweepSpec {
    use crate::util::MIB;
    let top = specs
        .iter()
        .map(covering_capacity_bound)
        .fold(128 * MIB, u64::max);
    let mut capacities = Vec::new();
    let mut c = 16 * MIB;
    while c <= top {
        capacities.push(c);
        c += 16 * MIB;
    }
    SweepSpec {
        capacities,
        banks: vec![1, 2, 4, 8, 16, 32],
        alphas: vec![0.9],
        policies: full_policy_axis(),
    }
}

/// Deterministic workload label used in reports and regret columns.
pub fn workload_label(spec: &ExperimentSpec) -> String {
    match spec.workload {
        Workload::Prefill { seq } => format!("{}-prefill{}", spec.model.name, seq),
        Workload::Decode { prompt, gen } => {
            format!("{}-decode{}+{}", spec.model.name, prompt, gen)
        }
        Workload::Serving(p) => format!(
            "{}-serve-r{}-c{}-s{}",
            spec.model.name, p.requests, p.concurrency, p.seed
        ),
    }
}

/// Execute every spec and collect its Stage-II sweep as a
/// [`WorkloadSweep`], streaming through the fused engine when an
/// explicit grid makes that possible.
fn collect_sweeps(
    ctx: &ApiContext,
    specs: &[ExperimentSpec],
    grid: Option<&SweepSpec>,
) -> Result<Vec<WorkloadSweep>> {
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let name = workload_label(spec);
        let effective = grid.cloned().or_else(|| spec.sweep.clone());
        let ws = match spec.workload {
            Workload::Serving(_) => {
                let g = match effective {
                    Some(g) => g,
                    None => spec.serving_arena_grid()?,
                };
                // Fused: occupancy streams into the sweep engine; no
                // materialized trace at serving scale.
                let (run, s2) = spec.serve_fused_with(ctx, &g)?;
                WorkloadSweep {
                    name,
                    end_cycles: run.result.total_cycles,
                    points: s2.points,
                }
            }
            _ => match effective {
                Some(g) if spec.hierarchy.is_none() => {
                    // Fused single-sequence path.
                    let mut streamed = spec.clone();
                    streamed.sweep = Some(g);
                    let (summary, points) = streamed.stream_stage2(ctx)?;
                    WorkloadSweep {
                        name,
                        end_cycles: summary.total_cycles(),
                        points,
                    }
                }
                grid => {
                    // Materialize: either no grid anywhere (the paper
                    // grid derives from the observed peak) or the spec
                    // is hierarchy-aware, in which case Stage II has to
                    // walk the trace to charge L2 spill/migration
                    // ([`crate::banking::sweep_hierarchy`] via
                    // `Stage2Run`'s dispatch).
                    let s1 = spec.run_stage1(ctx)?;
                    let s2 = match &grid {
                        Some(g) => s1.stage2_with(ctx, g)?,
                        None => s1.stage2(ctx)?,
                    };
                    WorkloadSweep {
                        name,
                        end_cycles: s1.result.total_cycles,
                        points: s2.shared().to_vec(),
                    }
                }
            },
        };
        out.push(ws);
    }
    Ok(out)
}

/// The batch portfolio entry point: run every spec (serving specs via
/// the fused serving pipeline, single-sequence specs via fused streaming
/// when a grid is known), then optimize across all of them. See
/// [`crate::banking::optimize::optimize`] for the frontier/portfolio
/// semantics.
pub fn run_portfolio(
    ctx: &ApiContext,
    specs: &[ExperimentSpec],
    opts: &PortfolioOptions,
) -> Result<PortfolioRun> {
    let workloads = collect_sweeps(ctx, specs, opts.grid.as_ref())?;
    let result = optimize(
        &workloads,
        &opts.constraints,
        opts.epsilon,
        opts.weights.as_deref(),
    )?;
    Ok(PortfolioRun { workloads, result })
}

/// One frontier configuration's offline prediction vs its Stage-III
/// online observation on one workload.
#[derive(Debug, Clone)]
pub struct OnlineValidation {
    pub workload: String,
    pub key: ConfigKey,
    /// Offline Stage-II total energy of the configuration, joules.
    pub predicted_e_j: f64,
    /// Online (stall-adjusted) total energy, joules.
    pub observed_e_j: f64,
    /// `(observed - predicted) / predicted`, percent (0 for a zero
    /// prediction). Positive = the offline model underestimated.
    pub energy_delta_pct: f64,
    /// The offline wake-exposure bound
    /// ([`crate::banking::optimize::wake_exposure_pct`]), percent.
    pub predicted_wake_pct: f64,
    /// Observed stall share of the run, percent of the trace length.
    pub observed_stall_pct: f64,
    /// Stage-I run length (no stalls), cycles.
    pub trace_cycles: u64,
    /// Cycles the execution stalled waiting for bank wake-ups.
    pub stall_cycles: u64,
    /// Level-rise instants that woke at least one gated bank.
    pub wake_events: u64,
}

impl OnlineValidation {
    /// Stall-adjusted end-to-end cycle count.
    pub fn end_cycles(&self) -> u64 {
        self.trace_cycles + self.stall_cycles
    }
}

/// Stage-III validation pass over a portfolio run: replay every
/// per-workload Pareto-frontier configuration online
/// ([`crate::banking::online::OnlineGateSim`]) against its workload and
/// report predicted-vs-observed energy and stall deltas per config —
/// the execution-driven check that the offline optimizer's picks
/// survive wake-latency timing feedback.
///
/// `specs` must be the slice the portfolio was collected from (same
/// order); each workload is simulated **once** (materialized), then
/// every frontier configuration replays against that trace. Output
/// order is deterministic: workloads in input order, frontier
/// configurations in canonical frontier order.
///
/// The per-configuration replays are independent, so they shard across
/// scoped worker threads (one detected core each) the same way
/// [`crate::banking::fused::sweep_fused`] shards ladder groups. Rows are
/// reassembled in frontier order regardless of completion order, so the
/// output — and anything rendered from it
/// ([`crate::report::tables::validation_csv`] /
/// [`crate::report::tables::validation_table`]) — is byte-identical at
/// any thread count. Use [`online_validate_with`] to pin the worker
/// count explicitly.
pub fn online_validate(
    ctx: &ApiContext,
    specs: &[ExperimentSpec],
    run: &PortfolioRun,
) -> Result<Vec<OnlineValidation>> {
    online_validate_with(ctx, specs, run, default_validate_jobs())
}

/// Default Stage-III validation parallelism: one worker per detected
/// core (1 when detection fails).
pub fn default_validate_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// [`online_validate`] with an explicit worker count. `jobs <= 1` runs
/// strictly sequentially; any value produces byte-identical output.
pub fn online_validate_with(
    ctx: &ApiContext,
    specs: &[ExperimentSpec],
    run: &PortfolioRun,
    jobs: usize,
) -> Result<Vec<OnlineValidation>> {
    ensure!(
        specs.len() == run.result.frontiers.len(),
        "online_validate: {} specs for {} frontiers (pass the spec slice \
         the portfolio was collected from)",
        specs.len(),
        run.result.frontiers.len()
    );
    let mut out = Vec::new();
    for (spec, frontier) in specs.iter().zip(&run.result.frontiers) {
        ensure!(
            workload_label(spec) == frontier.workload,
            "online_validate: spec `{}` does not match frontier workload \
             `{}` (order must be preserved)",
            workload_label(spec),
            frontier.workload
        );
        // One materialized Stage-I run per workload; every frontier
        // config replays against its borrowed trace. Hierarchy-aware
        // specs replay through the L2-spill simulator so observed
        // energy includes migration and L2 leakage.
        let run = spec.materialize(ctx)?;
        out.extend(validate_frontier_with(
            &ctx.cacti,
            run.trace(),
            run.stats(),
            frontier,
            spec.freq_ghz(),
            jobs,
            spec.hierarchy.as_ref(),
        )?);
    }
    Ok(out)
}

/// Replay every configuration of one workload frontier against an
/// already-materialized trace, sharding the independent replays across
/// up to `jobs` scoped worker threads.
///
/// Determinism: workers own contiguous frontier *chunks* and results are
/// concatenated in chunk order (never completion order), so the rows
/// come back in frontier order and the output is byte-identical at any
/// `jobs`. The first failing configuration's error (in frontier order)
/// propagates. The lab executor's `validate` jobs and
/// [`online_validate`] share this single implementation.
pub fn validate_frontier(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    frontier: &WorkloadFrontier,
    freq_ghz: f64,
    jobs: usize,
) -> Result<Vec<OnlineValidation>> {
    validate_frontier_with(cacti, trace, stats, frontier, freq_ghz, jobs, None)
}

/// [`validate_frontier`] with an optional L1+L2 hierarchy. `None` is the
/// flat replay, bit-identical to the historical path. `Some` routes each
/// replay through [`crate::banking::replay_hierarchy`] so observed
/// energy carries the L2 spill charge (migration + L2 leakage) the
/// offline hierarchy-aware sweep predicted.
pub fn validate_frontier_with(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    frontier: &WorkloadFrontier,
    freq_ghz: f64,
    jobs: usize,
    hierarchy: Option<&crate::banking::HierarchyConfig>,
) -> Result<Vec<OnlineValidation>> {
    let replay_one = |fp: &FrontierPoint| -> Result<OnlineValidation> {
        let config = OnlineConfig::of_point(&fp.point);
        let replay = crate::banking::replay_hierarchy(
            cacti,
            trace,
            stats,
            config,
            freq_ghz,
            false, // totals only; no timelines for a whole frontier
            hierarchy,
        )?;
        let observed_e_j = replay.e_total_j();
        let report = replay.report;
        // Flat replays keep the historical eval-vs-eval delta; hierarchy
        // replays compare L2-inclusive totals (the predicted point was
        // collapsed, so its eval already folds the L2 charge in).
        let predicted_e_j = fp.point.eval.e_total_j();
        let energy_delta_pct = if replay.l2.is_none() {
            report.eval.delta_pct(&fp.point.eval)
        } else if predicted_e_j == 0.0 {
            0.0
        } else {
            (observed_e_j - predicted_e_j) / predicted_e_j * 100.0
        };
        Ok(OnlineValidation {
            workload: frontier.workload.clone(),
            key: ConfigKey::of(&fp.point),
            predicted_e_j,
            observed_e_j,
            energy_delta_pct,
            predicted_wake_pct: fp.wake_exposure_pct,
            observed_stall_pct: report.stall_pct(),
            trace_cycles: report.trace_cycles,
            stall_cycles: report.stall_cycles,
            wake_events: report.wake_events,
        })
    };
    let fps = &frontier.frontier;
    let jobs = jobs.clamp(1, fps.len().max(1));
    if jobs <= 1 {
        return fps.iter().map(replay_one).collect();
    }
    let per = fps.len().div_ceil(jobs);
    let replay_one = &replay_one;
    let chunks: Result<Vec<Vec<OnlineValidation>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = fps
            .chunks(per)
            .map(|chunk| {
                scope.spawn(move || {
                    chunk.iter().map(replay_one).collect::<Result<Vec<_>>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("validation worker panicked"))
            .collect()
    });
    Ok(chunks?.into_iter().flatten().collect())
}

impl PortfolioRun {
    /// Convenience wrapper around [`online_validate`].
    pub fn online_validate(
        &self,
        ctx: &ApiContext,
        specs: &[ExperimentSpec],
    ) -> Result<Vec<OnlineValidation>> {
        online_validate(ctx, specs, self)
    }
}

impl ExperimentSpec {
    /// One-spec convenience: run this spec end to end (fused whenever a
    /// grid is known — see [`run_portfolio`]) and optimize its sweep.
    /// The single-workload portfolio is trivially the workload's own
    /// frontier; use [`run_portfolio`] for cross-workload selection.
    pub fn optimize(
        &self,
        ctx: &ApiContext,
        constraints: &Constraints,
        epsilon: f64,
    ) -> Result<OptimizeResult> {
        let workloads = collect_sweeps(ctx, std::slice::from_ref(self), None)?;
        Ok(optimize(&workloads, constraints, epsilon, None)?)
    }
}

impl Stage2Run<'_> {
    /// Run the Pareto optimizer over this run's shared-SRAM sweep:
    /// constraint filtering + ε-dominance frontier (the single-workload
    /// portfolio is trivially its own optimum).
    pub fn optimize(
        &self,
        constraints: &Constraints,
        epsilon: f64,
    ) -> Result<OptimizeResult> {
        let w = WorkloadSweep {
            name: self.stage1.result.workload.clone(),
            end_cycles: self.stage1.result.total_cycles,
            points: self.shared().to_vec(),
        };
        Ok(optimize(&[w], constraints, epsilon, None)?)
    }
}

impl ServingSweep {
    /// Run the Pareto optimizer over this serving sweep.
    pub fn optimize(
        &self,
        constraints: &Constraints,
        epsilon: f64,
    ) -> Result<OptimizeResult> {
        let w = WorkloadSweep {
            name: self.workload.clone(),
            end_cycles: self.end_cycles,
            points: self.points.clone(),
        };
        Ok(optimize(&[w], constraints, epsilon, None)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::optimize::ConfigKey;
    use crate::banking::GatingPolicy;
    use crate::config::tiny;
    use crate::serving::ServingParams;
    use crate::util::MIB;
    use crate::workload::{TINY_GQA, TINY_MHA};

    fn shared_grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB, 8 * MIB],
            banks: vec![1, 2, 4, 8],
            alphas: vec![0.9],
            policies: vec![
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        }
    }

    fn decode_spec(model: crate::workload::ModelPreset) -> ExperimentSpec {
        ExperimentSpec::builder()
            .model(model)
            .decode(32, 16)
            .accel(tiny())
            .build()
            .unwrap()
    }

    fn serving_spec() -> ExperimentSpec {
        let mut p = ServingParams::new(16, 4, 7);
        p.prompt_min = 4;
        p.prompt_max = 32;
        p.gen_min = 2;
        p.gen_max = 16;
        p.page_tokens = 8;
        p.mean_arrival_gap = 50_000;
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap()
    }

    #[test]
    fn portfolio_over_mixed_workloads_end_to_end() {
        let ctx = ApiContext::new();
        let specs = vec![decode_spec(TINY_MHA), decode_spec(TINY_GQA), serving_spec()];
        let opts = PortfolioOptions {
            grid: Some(shared_grid()),
            ..Default::default()
        };
        let run = run_portfolio(&ctx, &specs, &opts).unwrap();
        assert_eq!(run.workloads.len(), 3);
        assert_eq!(run.result.frontiers.len(), 3);
        for f in &run.result.frontiers {
            assert!(!f.frontier.is_empty(), "{} frontier empty", f.workload);
            assert!(f.feasible > 0);
        }
        let best = run.result.robust_best().expect("portfolio non-empty");
        assert!(best.worst_regret_pct >= 0.0);
        assert_eq!(best.regret_pct.len(), 3);
        // Workload labels are deterministic and distinct.
        assert_eq!(run.result.workload_names[0], "tiny-mha-decode32+16");
        assert_eq!(run.result.workload_names[1], "tiny-gqa-decode32+16");
        assert!(run.result.workload_names[2].starts_with("tiny-gqa-serve-r16-c4-s7"));
    }

    #[test]
    fn run_portfolio_is_deterministic() {
        let ctx = ApiContext::new();
        let specs = vec![decode_spec(TINY_GQA), serving_spec()];
        let opts = PortfolioOptions {
            grid: Some(shared_grid()),
            ..Default::default()
        };
        let a = run_portfolio(&ctx, &specs, &opts).unwrap();
        let b = run_portfolio(&ctx, &specs, &opts).unwrap();
        assert_eq!(a.result.portfolio.len(), b.result.portfolio.len());
        for (x, y) in a.result.portfolio.iter().zip(&b.result.portfolio) {
            assert_eq!(x.key, y.key);
            assert_eq!(
                x.worst_regret_pct.to_bits(),
                y.worst_regret_pct.to_bits(),
                "{:?}",
                x.key
            );
            for (ex, ey) in x.energy_j.iter().zip(&y.energy_j) {
                assert_eq!(ex.to_bits(), ey.to_bits());
            }
        }
        for (fa, fb) in a.result.frontiers.iter().zip(&b.result.frontiers) {
            assert_eq!(fa.frontier.len(), fb.frontier.len());
            for (x, y) in fa.frontier.iter().zip(&fb.frontier) {
                assert_eq!(ConfigKey::of(&x.point), ConfigKey::of(&y.point));
            }
        }
    }

    #[test]
    fn fused_portfolio_matches_materialized_sweeps() {
        // The streamed (SweepSink) collection path must hand the
        // optimizer the exact same points as materialized Stage II.
        let ctx = ApiContext::new();
        let spec = decode_spec(TINY_GQA);
        let grid = shared_grid();
        let run = run_portfolio(
            &ctx,
            std::slice::from_ref(&spec),
            &PortfolioOptions {
                grid: Some(grid.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let s1 = spec.run_stage1(&ctx).unwrap();
        let reference = s1.stage2_with(&ctx, &grid).unwrap();
        let streamed = &run.workloads[0].points;
        assert_eq!(streamed.len(), reference.shared().len());
        for (a, b) in streamed.iter().zip(reference.shared()) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
        }
        // And the single-workload handle entry point agrees.
        let via_handle = reference
            .optimize(&Constraints::default(), 0.0)
            .unwrap();
        assert_eq!(
            via_handle.frontiers[0].frontier.len(),
            run.result.frontiers[0].frontier.len()
        );
    }

    #[test]
    fn spec_level_optimize_convenience() {
        let ctx = ApiContext::new();
        let mut spec = decode_spec(TINY_GQA);
        spec.sweep = Some(shared_grid());
        let r = spec.optimize(&ctx, &Constraints::default(), 0.0).unwrap();
        assert_eq!(r.frontiers.len(), 1);
        assert_eq!(r.workload_names[0], "tiny-gqa-decode32+16");
        assert!(!r.frontiers[0].frontier.is_empty());
    }

    #[test]
    fn online_validate_covers_every_frontier_config() {
        let ctx = ApiContext::new();
        let specs = vec![decode_spec(TINY_GQA), serving_spec()];
        let opts = PortfolioOptions {
            grid: Some(shared_grid()),
            ..Default::default()
        };
        let run = run_portfolio(&ctx, &specs, &opts).unwrap();
        let vals = online_validate(&ctx, &specs, &run).unwrap();
        let want: usize = run
            .result
            .frontiers
            .iter()
            .map(|f| f.frontier.len())
            .sum();
        assert_eq!(vals.len(), want);
        // Rows follow (workload, frontier) order and reconcile with the
        // offline predictions they validate.
        let mut rows = vals.iter();
        for f in &run.result.frontiers {
            for fp in &f.frontier {
                let v = rows.next().expect("one row per frontier config");
                assert_eq!(v.workload, f.workload);
                assert_eq!(v.key, ConfigKey::of(&fp.point));
                assert_eq!(
                    v.predicted_e_j.to_bits(),
                    fp.point.eval.e_total_j().to_bits()
                );
                assert!(v.observed_e_j.is_finite() && v.observed_e_j >= 0.0);
                assert!(v.energy_delta_pct.is_finite());
                assert!(v.observed_stall_pct.is_finite() && v.observed_stall_pct >= 0.0);
                assert_eq!(v.end_cycles(), v.trace_cycles + v.stall_cycles);
                if v.wake_events == 0 {
                    assert_eq!(v.stall_cycles, 0);
                }
            }
        }
        assert!(rows.next().is_none(), "no extra validation rows");
        // Determinism: a second pass is bit-identical.
        let again = run.online_validate(&ctx, &specs).unwrap();
        for (a, b) in vals.iter().zip(&again) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.observed_e_j.to_bits(), b.observed_e_j.to_bits());
            assert_eq!(a.stall_cycles, b.stall_cycles);
        }
        // Mismatched spec slices are a typed error, not a silent zip.
        assert!(online_validate(&ctx, &specs[..1], &run).is_err());
    }

    #[test]
    fn parallel_validation_is_byte_identical_to_sequential() {
        // The Stage-III validation pass shards frontier replays across
        // worker threads; the assembled report must not depend on
        // completion order. Compare the *rendered* artifacts — the CSV
        // and the text table, the bytes the CI gates diff — across
        // jobs=1, jobs=8, and the auto default.
        use crate::report::tables::{validation_csv, validation_table};
        let ctx = ApiContext::new();
        let specs = vec![decode_spec(TINY_GQA), serving_spec()];
        let opts = PortfolioOptions {
            grid: Some(shared_grid()),
            ..Default::default()
        };
        let run = run_portfolio(&ctx, &specs, &opts).unwrap();
        let seq = online_validate_with(&ctx, &specs, &run, 1).unwrap();
        let par = online_validate_with(&ctx, &specs, &run, 8).unwrap();
        let auto = online_validate(&ctx, &specs, &run).unwrap();
        assert!(
            seq.len() > 1,
            "need a multi-config frontier to exercise sharding"
        );
        assert_eq!(validation_csv(&seq), validation_csv(&par));
        assert_eq!(validation_csv(&seq), validation_csv(&auto));
        assert_eq!(
            validation_table(&seq).render(),
            validation_table(&par).render()
        );
        // Row-level bit identity too (the CSV already implies it, but a
        // field-level failure message is more useful than a text diff).
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.workload, b.workload);
            assert_eq!(a.observed_e_j.to_bits(), b.observed_e_j.to_bits());
            assert_eq!(
                a.energy_delta_pct.to_bits(),
                b.energy_delta_pct.to_bits()
            );
            assert_eq!(a.stall_cycles, b.stall_cycles);
            assert_eq!(a.wake_events, b.wake_events);
        }
    }

    #[test]
    fn hierarchy_portfolio_admits_spill_and_validates_online() {
        use crate::banking::HierarchyConfig;
        let ctx = ApiContext::new();
        let flat = decode_spec(TINY_GQA);
        let s1 = flat.run_stage1(&ctx).unwrap();
        let peak = s1.trace().peak_needed();
        assert!(peak > 1, "tiny decode must have non-trivial occupancy");
        // A grid whose only capacity sits below the observed peak: the
        // flat sweep skips it as infeasible, the hierarchy-aware sweep
        // admits it by spilling the excess to L2.
        let below = (peak / 2).max(1);
        let grid = SweepSpec {
            capacities: vec![below],
            banks: vec![1, 2],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        let mut spec = flat;
        spec.hierarchy = Some(HierarchyConfig::new(peak));
        let opts = PortfolioOptions {
            grid: Some(grid),
            ..Default::default()
        };
        let run = run_portfolio(&ctx, std::slice::from_ref(&spec), &opts).unwrap();
        let points = &run.workloads[0].points;
        assert_eq!(points.len(), 2, "both bank counts admitted via L2 spill");
        for p in points {
            assert_eq!(p.eval.capacity, below);
            assert!(
                p.eval.e_total_j() > 0.0,
                "collapsed point carries migration + L2 leak energy"
            );
        }
        // Stage-III validation replays through the spill simulator: the
        // sub-peak capacity would be a hard InfeasibleCapacity error on
        // the flat replay path.
        let vals = online_validate(&ctx, std::slice::from_ref(&spec), &run).unwrap();
        assert_eq!(vals.len(), run.result.frontiers[0].frontier.len());
        assert!(!vals.is_empty());
        for v in &vals {
            assert!(v.observed_e_j.is_finite() && v.observed_e_j > 0.0);
            assert!(v.energy_delta_pct.is_finite());
        }
        // Determinism across a second full pass.
        let again = run_portfolio(&ctx, std::slice::from_ref(&spec), &opts).unwrap();
        for (a, b) in points.iter().zip(&again.workloads[0].points) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
        }
    }

    #[test]
    fn serving_sweep_optimize_entry_point() {
        let ctx = ApiContext::new();
        let (run, s2) = serving_spec()
            .serve_fused_with(&ctx, &shared_grid())
            .unwrap();
        let r = s2.optimize(&Constraints::default(), 0.0).unwrap();
        assert_eq!(r.frontiers.len(), 1);
        assert_eq!(r.frontiers[0].end_cycles, run.result.total_cycles);
        assert!(!r.frontiers[0].frontier.is_empty());
    }
}
