//! `BatchRunner`: execute many [`ExperimentSpec`]s concurrently.
//!
//! The ROADMAP's north star is serving many scenarios fast: a grid of
//! (model × workload × accelerator × sweep) specs runs as one parallel
//! batch across OS threads, with results memoized by
//! [`ExperimentSpec::content_hash`] so duplicated specs (common in
//! sweep grids that share a baseline) simulate exactly once. Simulation
//! is deterministic, so the batch output is byte-identical to a naive
//! sequential loop — `run_sequential` exists precisely to assert that.
//!
//! Memoization is in-memory per batch; results persist across processes
//! through the content-addressed lab store: `repro batch --lab DIR`
//! writes each result via [`crate::lab::store::persist_batch`], and
//! whole campaigns run resumable through `repro lab run`
//! ([`crate::lab`]), which skips any job whose artifacts already exist.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::banking::SweepPoint;
use crate::util::MIB;

use super::spec::ExperimentSpec;
use super::stage::{ApiContext, Stage1Run};

/// Shared per-unique-spec outcome (Stage I always; Stage II iff the
/// spec carries a sweep grid).
#[derive(Clone)]
struct Computed {
    stage1: Arc<Stage1Run>,
    sweep: Arc<Vec<(String, Vec<SweepPoint>)>>,
}

/// One batch entry's results. Duplicated input specs share the same
/// `Arc`s (memoization) — compare with [`Arc::ptr_eq`].
#[derive(Clone)]
pub struct BatchResult {
    pub spec: ExperimentSpec,
    /// The spec's content hash (memoization key).
    pub hash: u64,
    pub stage1: Arc<Stage1Run>,
    /// Stage-II evaluations per memory; empty when the spec had no
    /// sweep grid.
    pub sweep: Arc<Vec<(String, Vec<SweepPoint>)>>,
}

impl BatchResult {
    /// Deterministic text report (stable field order and float
    /// formatting), suitable for byte-for-byte comparison between
    /// parallel and sequential executions.
    pub fn report(&self) -> String {
        let r = &self.stage1.result;
        let mut out = String::new();
        out.push_str(&format!(
            "=== {} {:?} on {} [spec {:016x}] ===\n",
            self.spec.model.name, self.spec.workload, self.spec.accel.name, self.hash
        ));
        out.push_str(&format!(
            "stage1: cycles={} time_ms={:.6} peak_needed_mib={:.6} feasible={} \
             reads={} writes={} on_chip_j={:.9}\n",
            r.total_cycles,
            r.seconds() * 1e3,
            r.peak_needed() as f64 / MIB as f64,
            r.feasible(),
            r.stats.reads,
            r.stats.writes,
            self.stage1.energy.on_chip_j(),
        ));
        for (mem, points) in self.sweep.iter() {
            for p in points {
                out.push_str(&format!(
                    "stage2 {mem}: C_mib={} B={} alpha={:.3} policy={} \
                     e_total_j={:.9} delta_e_pct={:.6} area_mm2={:.6}\n",
                    p.eval.capacity / MIB,
                    p.eval.banks,
                    p.eval.alpha,
                    p.eval.policy.label(),
                    p.eval.e_total_j(),
                    p.delta_e_pct(),
                    p.eval.area_mm2,
                ));
            }
        }
        out
    }
}

/// Parallel, memoizing executor over experiment specs.
pub struct BatchRunner {
    ctx: ApiContext,
    threads: usize,
    derive_sweep: bool,
}

impl Default for BatchRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchRunner {
    pub fn new() -> Self {
        Self::with_context(ApiContext::default())
    }

    pub fn with_context(ctx: ApiContext) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self {
            ctx,
            threads,
            derive_sweep: false,
        }
    }

    /// Cap the worker-thread count (>= 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Run Stage II for *every* spec, deriving the paper grid from the
    /// Stage-I peak when a spec carries no explicit sweep. Keeps the
    /// sweep inside the batch's parallelism and memoization instead of
    /// leaving it to a serial post-pass.
    pub fn derive_sweep(mut self, yes: bool) -> Self {
        self.derive_sweep = yes;
        self
    }

    pub fn context(&self) -> &ApiContext {
        &self.ctx
    }

    /// Execute all `specs`, deduplicated by content hash, across up to
    /// `self.threads` worker threads. Output order matches input order;
    /// duplicated specs share `Arc`s with their first occurrence.
    pub fn run(&self, specs: &[ExperimentSpec]) -> Result<Vec<BatchResult>> {
        for s in specs {
            s.validate()?;
            // Fail fast with a pointer to the right entry point instead
            // of erroring later inside a worker thread.
            if matches!(s.workload, crate::workload::Workload::Serving(_)) {
                return Err(anyhow!(
                    "BatchRunner batches single-sequence specs; serving spec \
                     {:016x} runs via ExperimentSpec::run_serving",
                    s.content_hash()
                ));
            }
        }
        // Dedupe, preserving first-seen order (hash + structural
        // equality, so a hash collision cannot alias two specs).
        let mut unique: Vec<(u64, &ExperimentSpec)> = Vec::new();
        let mut index_of: Vec<usize> = Vec::with_capacity(specs.len());
        for s in specs {
            let h = s.content_hash();
            match unique.iter().position(|&(uh, us)| uh == h && us == s) {
                Some(i) => index_of.push(i),
                None => {
                    unique.push((h, s));
                    index_of.push(unique.len() - 1);
                }
            }
        }

        let n = unique.len();
        let slots: Vec<Mutex<Option<Result<Computed>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let outcome = self.compute(unique[i].1);
                    *slots[i].lock().expect("slot poisoned") = Some(outcome);
                });
            }
        });

        let mut computed: Vec<Computed> = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            let outcome = slot
                .into_inner()
                .expect("slot poisoned")
                .ok_or_else(|| anyhow!("batch worker never completed spec {i}"))?;
            computed.push(outcome?);
        }

        Ok(index_of
            .into_iter()
            .zip(specs)
            .map(|(u, s)| BatchResult {
                spec: s.clone(),
                hash: unique[u].0,
                stage1: computed[u].stage1.clone(),
                sweep: computed[u].sweep.clone(),
            })
            .collect())
    }

    /// Naive reference executor: one spec after another, no threads, no
    /// memoization. `run` must produce byte-identical reports.
    pub fn run_sequential(&self, specs: &[ExperimentSpec]) -> Result<Vec<BatchResult>> {
        specs
            .iter()
            .map(|s| {
                let c = self.compute(s)?;
                Ok(BatchResult {
                    spec: s.clone(),
                    hash: s.content_hash(),
                    stage1: c.stage1,
                    sweep: c.sweep,
                })
            })
            .collect()
    }

    fn compute(&self, spec: &ExperimentSpec) -> Result<Computed> {
        let s1 = spec.run_stage1(&self.ctx)?;
        let sweep = if spec.sweep.is_some() || self.derive_sweep {
            let s2 = s1.stage2(&self.ctx)?;
            Arc::new(s2.per_memory)
        } else {
            Arc::new(Vec::new())
        };
        Ok(Computed {
            stage1: Arc::new(s1),
            sweep,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::{GatingPolicy, SweepSpec};
    use crate::config::tiny;
    use crate::workload::{TINY_GQA, TINY_MHA};

    fn spec(model: crate::workload::ModelPreset, seq: u32) -> ExperimentSpec {
        ExperimentSpec::builder()
            .model(model)
            .prefill(seq)
            .accel(tiny())
            .sweep(SweepSpec {
                capacities: vec![2 * MIB, 4 * MIB],
                banks: vec![1, 4],
                alphas: vec![0.9],
                policies: vec![GatingPolicy::Aggressive],
            })
            .build()
            .unwrap()
    }

    #[test]
    fn memoizes_duplicate_specs() {
        let specs = vec![spec(TINY_GQA, 64), spec(TINY_MHA, 64), spec(TINY_GQA, 64)];
        let out = BatchRunner::new().threads(2).run(&specs).unwrap();
        assert_eq!(out.len(), 3);
        assert!(Arc::ptr_eq(&out[0].stage1, &out[2].stage1), "memoized");
        assert!(Arc::ptr_eq(&out[0].sweep, &out[2].sweep));
        assert!(!Arc::ptr_eq(&out[0].stage1, &out[1].stage1));
        assert_eq!(out[0].hash, out[2].hash);
        assert_ne!(out[0].hash, out[1].hash);
    }

    #[test]
    fn parallel_matches_sequential_byte_for_byte() {
        let specs = vec![spec(TINY_GQA, 64), spec(TINY_MHA, 48), spec(TINY_GQA, 64)];
        let runner = BatchRunner::new().threads(2);
        let par: Vec<String> =
            runner.run(&specs).unwrap().iter().map(|r| r.report()).collect();
        let seq: Vec<String> = runner
            .run_sequential(&specs)
            .unwrap()
            .iter()
            .map(|r| r.report())
            .collect();
        assert_eq!(par, seq);
        assert!(par[0].contains("stage2"), "sweep points rendered");
    }

    #[test]
    fn derive_sweep_fills_in_paper_grid() {
        let mut sp = spec(TINY_GQA, 64);
        sp.sweep = None;
        // Without the knob: Stage I only.
        let plain = BatchRunner::new().run(std::slice::from_ref(&sp)).unwrap();
        assert!(plain[0].sweep.is_empty());
        // With it: the paper grid derived from the Stage-I peak.
        let derived = BatchRunner::new()
            .derive_sweep(true)
            .run(std::slice::from_ref(&sp))
            .unwrap();
        assert_eq!(derived[0].sweep.len(), 1);
        assert!(!derived[0].sweep[0].1.is_empty(), "grid never empty");
    }

    #[test]
    fn empty_batch_is_fine() {
        let out = BatchRunner::new().run(&[]).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn invalid_spec_fails_fast() {
        let mut bad = spec(TINY_GQA, 64);
        bad.workload = crate::workload::Workload::Prefill { seq: 0 };
        assert!(BatchRunner::new().run(&[bad]).is_err());
    }

    #[test]
    fn serving_spec_rejected_with_pointer_to_run_serving() {
        let mut sp = spec(TINY_GQA, 64);
        sp.workload = crate::workload::Workload::Serving(
            crate::serving::ServingParams::new(8, 2, 1),
        );
        let err = BatchRunner::new().run(&[sp]).unwrap_err();
        assert!(err.to_string().contains("run_serving"), "{err:#}");
    }
}
