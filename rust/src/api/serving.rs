//! Typed serving pipeline: `ExperimentSpec` (Serving workload) →
//! [`ServingRun`] → [`ServingSweep`].
//!
//! Mirrors the `Stage1Run`/`Stage2Run` handles: a `ServingSweep` is only
//! obtainable from a `&ServingRun`, so "sweep before simulate" stays
//! unrepresentable for the serving scenario too. The Stage-II evaluator
//! consumes the merged KV-arena trace through the exact same
//! [`crate::banking::sweep`](fn@crate::banking::sweep) entry point as
//! single-sequence traces.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::banking::online::{replay_trace, OnlineConfig, OnlineGateSim, OnlineReport};
use crate::banking::{sweep, GatingPolicy, SweepPoint, SweepSink, SweepSpec};
use crate::obs::WalSink;
use crate::serving::ServingParams;
use crate::sim::serving::{
    round_robin, simulate_serving, simulate_serving_with, ServingResult,
    ServingSimOptions,
};
use crate::trace::{OccupancyTrace, TeeSink, TraceSink};
use crate::util::MIB;
use crate::workload::Workload;

use super::spec::ExperimentSpec;
use super::stage::ApiContext;

/// Stage-I output of a serving scenario: the merged KV-arena occupancy
/// trace plus completion / traffic statistics.
#[derive(Debug, Clone)]
pub struct ServingRun {
    pub spec: ExperimentSpec,
    pub result: ServingResult,
}

/// Which scheduler executes a serving spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServingEngine {
    /// The event-driven engine ([`simulate_serving_with`]) — the
    /// default, and the only one that handles priority tiers, shared
    /// prefixes, and multi-model tenancy.
    #[default]
    Event,
    /// The retained round-by-round differential oracle
    /// ([`round_robin`]); bit-identical to the event engine on legacy
    /// scheduling and rejects the extensions.
    RoundRobin,
}

impl ExperimentSpec {
    /// The serving params of this spec, or an error for single-sequence
    /// workloads.
    pub fn serving_params(&self) -> Result<ServingParams> {
        match self.workload {
            Workload::Serving(p) => Ok(p),
            _ => bail!(
                "spec workload is {:?}; run_serving needs Workload::Serving \
                 (use ExperimentSpecBuilder::serving)",
                self.workload
            ),
        }
    }

    /// Execute the serving scenario (materialized trace) on the default
    /// event-driven engine.
    pub fn run_serving(&self) -> Result<ServingRun> {
        self.run_serving_with_engine(ServingEngine::Event)
    }

    /// Execute the serving scenario on an explicit engine — the CLI's
    /// `--engine round-robin` differential path.
    pub fn run_serving_with_engine(&self, engine: ServingEngine) -> Result<ServingRun> {
        self.validate()?;
        let params = self.serving_params()?;
        let result = match engine {
            ServingEngine::Event => simulate_serving(&self.model, params, &self.accel)?,
            ServingEngine::RoundRobin => round_robin(
                &self.model,
                params,
                &self.accel,
                ServingSimOptions::default(),
            )?,
        };
        Ok(ServingRun {
            spec: self.clone(),
            result,
        })
    }

    /// Execute the serving scenario streaming occupancy into `sink`
    /// without materializing the trace (O(1) trace memory). The returned
    /// run's trace is empty, so its Stage-II methods sweep nothing —
    /// peaks and averages live in the caller's sink.
    pub fn stream_serving(&self, sink: &mut dyn TraceSink) -> Result<ServingRun> {
        self.validate()?;
        let params = self.serving_params()?;
        let result = simulate_serving_with(
            &self.model,
            params,
            &self.accel,
            ServingSimOptions {
                sink: Some(sink),
                materialize: false,
            },
        )?;
        Ok(ServingRun {
            spec: self.clone(),
            result,
        })
    }

    /// Default Stage-II grid for a *fused* (streamed) serving run, where
    /// the trace peak is unknown until the simulation ends: one capacity
    /// — the provisioned KV-arena bound
    /// ([`crate::sim::serving::arena_capacity`]) rounded up to a 16 MiB
    /// step — with the same bank/policy axes as
    /// [`ServingRun::serving_grid`]. The materialized default instead
    /// tightens the capacity to the *observed* peak; pass the same
    /// explicit grid to both paths when comparing them.
    pub fn serving_arena_grid(&self) -> Result<SweepSpec> {
        // Typed errors for single-sequence specs and for degenerate
        // serving params (zero requests/concurrency would otherwise
        // produce a nonsensical zero-capacity grid downstream).
        self.serving_params()?.validate()?;
        // Shared bound/rounding formula with the optimizer's covering
        // grids — one definition, no drift.
        let capacity = super::optimize::covering_capacity_bound(self);
        Ok(serving_axes(capacity))
    }

    /// Fused Stage I + Stage II for a serving scenario: the simulation
    /// streams the KV-arena occupancy straight into the single-pass
    /// sweep engine ([`crate::banking::SweepSink`]), so the Stage-II
    /// answer is ready the moment the run completes and **no trace is
    /// ever materialized**. The grid is the spec's, or
    /// [`ExperimentSpec::serving_arena_grid`] when the spec left it open.
    ///
    /// With the same explicit grid, the returned sweep is byte-identical
    /// to `run_serving()` + `stage2_with` (the CI determinism gate
    /// asserts exactly that through `repro serve --fused`).
    pub fn serve_fused(&self, ctx: &ApiContext) -> Result<(ServingRun, ServingSweep)> {
        let grid = match &self.sweep {
            Some(g) => g.clone(),
            None => self.serving_arena_grid()?,
        };
        self.serve_fused_with(ctx, &grid)
    }

    /// Fused Stage I + Stage III for a serving scenario: the serving
    /// simulation streams the KV-arena occupancy straight into the
    /// online gating co-simulator
    /// ([`crate::banking::online::OnlineGateSim`]), replaying one chosen
    /// configuration with wake-latency stalls fed back into timing and
    /// **no materialized trace**. The serving-side twin of
    /// [`ExperimentSpec::stream_online`].
    pub fn serve_online(
        &self,
        ctx: &ApiContext,
        config: OnlineConfig,
    ) -> Result<(ServingRun, OnlineReport)> {
        let mut sim = OnlineGateSim::new(&ctx.cacti, config, self.freq_ghz())?;
        let run = self.stream_serving(&mut sim)?;
        let report = sim.into_report(&run.result.stats)?;
        Ok((run, report))
    }

    /// Fused serving run with an explicit Stage-II grid.
    pub fn serve_fused_with(
        &self,
        ctx: &ApiContext,
        grid: &SweepSpec,
    ) -> Result<(ServingRun, ServingSweep)> {
        self.validate()?;
        let params = self.serving_params()?;
        let mut sink = SweepSink::new(&ctx.cacti, grid, self.freq_ghz());
        let result = simulate_serving_with(
            &self.model,
            params,
            &self.accel,
            ServingSimOptions {
                sink: Some(&mut sink),
                materialize: false,
            },
        )?;
        let points = sink.into_points(&result.stats);
        let sweep = ServingSweep {
            workload: result.workload.clone(),
            end_cycles: result.total_cycles,
            spec: grid.clone(),
            points,
        };
        Ok((
            ServingRun {
                spec: self.clone(),
                result,
            },
            sweep,
        ))
    }

    /// [`ExperimentSpec::serve_fused`] with a write-ahead event log: the
    /// fused occupancy stream is teed into a [`WalSink`] *alongside* the
    /// single-pass sweep engine, so a fused run no longer has to choose
    /// between the Stage-II answer and the WAL artifact. Results are
    /// identical to `serve_fused` (the tee only observes), and the
    /// sealed log replays ([`crate::obs::replay_wal`]) to the exact
    /// merged trace a materialized run would record, with the run's
    /// stats attached. `run_id` is the spec's content hash; pass
    /// `wall_unix_ms = 0` for byte-deterministic logs.
    pub fn serve_fused_logged(
        &self,
        ctx: &ApiContext,
        wal_dir: &Path,
        wall_unix_ms: u64,
    ) -> Result<(ServingRun, ServingSweep)> {
        self.validate()?;
        let params = self.serving_params()?;
        let grid = match &self.sweep {
            Some(g) => g.clone(),
            None => self.serving_arena_grid()?,
        };
        let mut wal = WalSink::create(wal_dir, self.content_hash(), wall_unix_ms)
            .with_context(|| format!("creating WAL at {}", wal_dir.display()))?;
        let mut sink = SweepSink::new(&ctx.cacti, &grid, self.freq_ghz());
        let result = {
            let mut tee = TeeSink::new(vec![&mut sink, &mut wal]);
            simulate_serving_with(
                &self.model,
                params,
                &self.accel,
                ServingSimOptions {
                    sink: Some(&mut tee),
                    materialize: false,
                },
            )?
        };
        wal.close(Some(&result.stats))
            .with_context(|| format!("sealing WAL at {}", wal_dir.display()))?;
        let points = sink.into_points(&result.stats);
        let sweep = ServingSweep {
            workload: result.workload.clone(),
            end_cycles: result.total_cycles,
            spec: grid,
            points,
        };
        Ok((
            ServingRun {
                spec: self.clone(),
                result,
            },
            sweep,
        ))
    }
}

/// The serving bank/policy axes at one capacity: the paper's bank set
/// and all three gating policies — serving asks "which (B, policy) fits
/// this traffic", not "how small can the SRAM be".
fn serving_axes(capacity: u64) -> SweepSpec {
    SweepSpec {
        capacities: vec![capacity],
        banks: vec![1, 2, 4, 8, 16, 32],
        alphas: vec![0.9],
        policies: vec![
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ],
    }
}

impl ServingRun {
    /// Borrowed view of the merged KV-arena occupancy trace.
    pub fn trace(&self) -> &OccupancyTrace {
        &self.result.trace
    }

    /// Default Stage-II grid for serving traces: one capacity (the peak
    /// occupancy rounded up to a 16 MiB step), the paper's bank set, and
    /// all three gating policies — serving asks "which (B, policy) fits
    /// this traffic", not "how small can the SRAM be".
    pub fn serving_grid(&self) -> SweepSpec {
        let peak = self.trace().peak_occupied().max(1);
        let capacity = peak.div_ceil(16 * MIB).max(1) * 16 * MIB;
        serving_axes(capacity)
    }

    /// Stage II over the serving trace: the spec's grid, or
    /// [`ServingRun::serving_grid`] when the spec left it open. Errors
    /// (instead of panicking) if the trace is unfinalized.
    pub fn stage2(&self, ctx: &ApiContext) -> Result<ServingSweep> {
        let grid = self
            .spec
            .sweep
            .clone()
            .unwrap_or_else(|| self.serving_grid());
        self.stage2_with(ctx, &grid)
    }

    /// Stage III: replay one configuration online against the
    /// materialized serving trace (per-bank state machines, wake-stall
    /// timing feedback). See [`ExperimentSpec::serve_online`] for the
    /// streamed equivalent.
    pub fn replay_online(&self, ctx: &ApiContext, config: OnlineConfig) -> Result<OnlineReport> {
        Ok(replay_trace(
            &ctx.cacti,
            &self.result.trace,
            &self.result.stats,
            config,
            self.spec.freq_ghz(),
        )?)
    }

    /// Stage II with an explicit grid.
    pub fn stage2_with(&self, ctx: &ApiContext, grid: &SweepSpec) -> Result<ServingSweep> {
        let points = sweep(
            &ctx.cacti,
            &self.result.trace,
            &self.result.stats,
            grid,
            self.spec.freq_ghz(),
        )?;
        Ok(ServingSweep {
            workload: self.result.workload.clone(),
            end_cycles: self.result.total_cycles,
            spec: grid.clone(),
            points,
        })
    }
}

/// Stage-II output over a serving trace. Carries the workload label and
/// the run length so it can feed the Stage-II optimizer
/// (`ServingSweep::optimize`,
/// [`crate::banking::optimize`](mod@crate::banking::optimize)) standalone.
#[derive(Debug, Clone)]
pub struct ServingSweep {
    pub workload: String,
    /// Stage-I makespan in cycles (wake-exposure accounting).
    pub end_cycles: u64,
    pub spec: SweepSpec,
    pub points: Vec<SweepPoint>,
}

impl ServingSweep {
    /// Lowest-energy candidate.
    pub fn best(&self) -> Option<&SweepPoint> {
        self.points
            .iter()
            .min_by(|a, b| a.eval.e_total_j().total_cmp(&b.eval.e_total_j()))
    }

    /// Best ΔE% (negative = win over the unbanked, ungated reference).
    pub fn best_delta_pct(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.delta_e_pct())
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::workload::TINY_GQA;

    fn serving_spec() -> ExperimentSpec {
        let mut p = ServingParams::new(24, 4, 7);
        p.prompt_min = 4;
        p.prompt_max = 32;
        p.gen_min = 2;
        p.gen_max = 16;
        p.page_tokens = 8;
        p.mean_arrival_gap = 50_000;
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap()
    }

    #[test]
    fn run_serving_then_stage2_composes() {
        let ctx = ApiContext::new();
        let run = serving_spec().run_serving().unwrap();
        assert_eq!(run.result.completed, 24);
        assert!(run.trace().peak_needed() > 0);
        let s2 = run.stage2(&ctx).unwrap();
        assert!(!s2.points.is_empty());
        let best = s2.best().unwrap();
        assert!(best.eval.banks >= 1);
        // Banked gating must beat the unbanked reference on a serving
        // trace with arrival gaps and completion churn.
        assert!(s2.best_delta_pct() < 0.0);
    }

    #[test]
    fn run_stage1_rejects_serving_specs() {
        let ctx = ApiContext::new();
        let err = serving_spec().run_stage1(&ctx).unwrap_err();
        assert!(err.to_string().contains("run_serving"), "{err:#}");
    }

    #[test]
    fn run_serving_rejects_single_sequence_specs() {
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap();
        assert!(spec.run_serving().is_err());
    }

    #[test]
    fn serve_fused_matches_materialized_stage2_on_same_grid() {
        let ctx = ApiContext::new();
        let spec = serving_spec();
        let reference = spec.run_serving().unwrap();
        // Same explicit grid for both paths (the fused default derives
        // its capacity from the arena bound, not the observed peak).
        let grid = reference.serving_grid();
        let ref_sweep = reference.stage2_with(&ctx, &grid).unwrap();
        let (run, fused) = spec.serve_fused_with(&ctx, &grid).unwrap();
        assert_eq!(run.result.total_cycles, reference.result.total_cycles);
        assert_eq!(run.result.stats, reference.result.stats);
        assert_eq!(run.trace().samples().len(), 1, "no materialized trace");
        assert_eq!(fused.points.len(), ref_sweep.points.len());
        for (a, b) in fused.points.iter().zip(&ref_sweep.points) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
            assert_eq!(a.eval.policy, b.eval.policy);
            assert_eq!(
                a.eval.gated_fraction.to_bits(),
                b.eval.gated_fraction.to_bits()
            );
            assert_eq!(a.base_e_j.to_bits(), b.base_e_j.to_bits());
        }
    }

    #[test]
    fn serve_fused_default_grid_uses_arena_bound() {
        let ctx = ApiContext::new();
        let spec = serving_spec();
        let grid = spec.serving_arena_grid().unwrap();
        assert_eq!(grid.capacities.len(), 1);
        assert_eq!(grid.capacities[0] % (16 * crate::util::MIB), 0);
        assert!(
            grid.capacities[0]
                >= crate::sim::serving::arena_capacity(
                    &spec.model,
                    &spec.serving_params().unwrap()
                )
        );
        let (run, sweep) = spec.serve_fused(&ctx).unwrap();
        assert_eq!(run.result.completed, 24);
        assert!(!sweep.points.is_empty(), "arena bound must be feasible");
        assert!(sweep.best_delta_pct() < 0.0);
    }

    #[test]
    fn serve_online_matches_materialized_replay() {
        let ctx = ApiContext::new();
        let spec = serving_spec();
        let reference = spec.run_serving().unwrap();
        // Capacity from the arena bound so the replay is always feasible.
        let capacity = spec.serving_arena_grid().unwrap().capacities[0];
        let cfg = OnlineConfig::new(capacity, 8, 0.9, GatingPolicy::Aggressive);
        let materialized = reference.replay_online(&ctx, cfg).unwrap();
        let (run, streamed) = spec.serve_online(&ctx, cfg).unwrap();
        assert_eq!(run.result.total_cycles, reference.result.total_cycles);
        assert_eq!(run.trace().samples().len(), 1, "no materialized trace");
        assert_eq!(streamed.trace_cycles, materialized.trace_cycles);
        assert_eq!(streamed.stall_cycles, materialized.stall_cycles);
        assert_eq!(streamed.wake_events, materialized.wake_events);
        assert_eq!(
            streamed.eval.e_total_j().to_bits(),
            materialized.eval.e_total_j().to_bits()
        );
        assert_eq!(streamed.timeline_csv(), materialized.timeline_csv());
    }

    #[test]
    fn engine_selection_matches_and_oracle_rejects_extensions() {
        let spec = serving_spec();
        let ev = spec.run_serving_with_engine(ServingEngine::Event).unwrap();
        let rr = spec.run_serving_with_engine(ServingEngine::RoundRobin).unwrap();
        assert_eq!(ev.result.trace_hash(), rr.result.trace_hash());
        assert_eq!(ev.result.stats, rr.result.stats);
        assert_eq!(ev.result.total_cycles, rr.result.total_cycles);

        let mut p = spec.serving_params().unwrap();
        p.tiers = 2;
        let ext = ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap();
        assert!(ext.run_serving_with_engine(ServingEngine::RoundRobin).is_err());
        assert!(ext.run_serving_with_engine(ServingEngine::Event).is_ok());
    }

    #[test]
    fn serving_arena_grid_rejects_degenerate_specs() {
        let mut spec = serving_spec();
        let Workload::Serving(p) = &mut spec.workload else {
            unreachable!();
        };
        p.concurrency = 0;
        let err = spec.serving_arena_grid().unwrap_err();
        assert!(err.to_string().contains("concurrency"), "{err}");
    }

    #[test]
    fn serve_fused_logged_tees_wal_without_changing_results() {
        use crate::obs::replay_wal;
        let ctx = ApiContext::new();
        let spec = serving_spec();
        let dir = std::env::temp_dir().join(format!(
            "trapti-fused-wal-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);

        let (run_a, sweep_a) = spec.serve_fused(&ctx).unwrap();
        let (run_b, sweep_b) = spec.serve_fused_logged(&ctx, &dir, 0).unwrap();
        assert_eq!(run_a.result.total_cycles, run_b.result.total_cycles);
        assert_eq!(run_a.result.stats, run_b.result.stats);
        assert_eq!(sweep_a.points.len(), sweep_b.points.len());
        for (a, b) in sweep_a.points.iter().zip(&sweep_b.points) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.policy, b.eval.policy);
        }

        // The sealed WAL replays to the same merged trace a
        // materialized run records, stats attached.
        let replay = replay_wal(&dir).unwrap();
        assert!(replay.complete);
        assert_eq!(replay.run_id, spec.content_hash());
        let reference = spec.run_serving().unwrap();
        assert_eq!(replay.traces.len(), 1);
        assert_eq!(replay.traces[0].samples(), reference.trace().samples());
        assert_eq!(replay.traces[0].end_time(), reference.trace().end_time());
        assert_eq!(replay.stats.as_ref(), Some(&run_b.result.stats));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn streaming_run_matches_materialized_stats() {
        use crate::trace::OnlineStatsSink;
        let spec = serving_spec();
        let reference = spec.run_serving().unwrap();
        let mut online = OnlineStatsSink::new();
        let streamed = spec.stream_serving(&mut online).unwrap();
        assert_eq!(streamed.result.total_cycles, reference.result.total_cycles);
        assert_eq!(
            online.shared().unwrap().peak_needed(),
            reference.trace().peak_needed()
        );
        assert_eq!(streamed.trace().samples().len(), 1, "not materialized");
    }
}
