//! Typed stage handles: `ExperimentSpec` → [`Stage1Run`] → [`Stage2Run`].
//!
//! The handle types encode the paper's two-stage flow in the type
//! system: a `Stage2Run` can only be obtained from a `&Stage1Run` (and
//! borrows it), so "sweep before simulate" is unrepresentable, and the
//! Stage-II evaluator reads the occupancy trace through a borrowed view
//! instead of cloning it. Streaming-only runs return a
//! [`Stage1Summary`], which deliberately has *no* Stage-II methods —
//! its traces were never materialized.

use anyhow::{anyhow, Result};

use crate::banking::online::{replay_trace, OnlineConfig, OnlineGateSim, OnlineReport};
use crate::banking::{sweep, SweepPoint, SweepSink, SweepSpec};
use crate::cacti::CactiModel;
use crate::energy::{energy_breakdown, EnergyBreakdown, EnergyParams};
use crate::memory::{size_memory, SizingResult};
use crate::sim::{simulate, simulate_with, SimOptions, SimResult};
use crate::trace::{AccessStats, OccupancyTrace, TraceSink};
use crate::util::MIB;
use crate::workload::{build_workload, Workload, WorkloadGraph};

use super::serving::ServingRun;
use super::spec::ExperimentSpec;

/// Shared measurement context: CACTI characterization + energy
/// coefficients. One context serves any number of runs (it is `Sync`,
/// so `BatchRunner` shares it across threads).
#[derive(Debug, Clone, Default)]
pub struct ApiContext {
    pub cacti: CactiModel,
    pub energy: EnergyParams,
}

impl ApiContext {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Stage-I output bundle: the built workload graph, the cycle-level
/// simulation result (with materialized occupancy traces), and the
/// Fig. 7 energy breakdown.
#[derive(Debug, Clone)]
pub struct Stage1Run {
    pub spec: ExperimentSpec,
    pub graph: WorkloadGraph,
    pub result: SimResult,
    pub energy: EnergyBreakdown,
}

/// Stage-I output of a streaming run (`ExperimentSpec::stream_stage1`):
/// timing, stats and energy, but **no** materialized traces — occupancy
/// went to the caller's `TraceSink`. Consequently there are no Stage-II
/// methods on this type, and the inner `SimResult` is private: its
/// trace-derived accessors (`peak_needed`, `sram_trace`, …) would
/// silently report empty traces on a streaming run, so the summary only
/// exposes the queries that remain meaningful. Peaks/averages live in
/// the caller's sink (e.g. `trace::OnlineStatsSink`).
#[derive(Debug, Clone)]
pub struct Stage1Summary {
    pub spec: ExperimentSpec,
    pub graph: WorkloadGraph,
    pub energy: EnergyBreakdown,
    result: SimResult,
}

impl Stage1Summary {
    pub fn total_cycles(&self) -> u64 {
        self.result.total_cycles
    }

    pub fn seconds(&self) -> f64 {
        self.result.seconds()
    }

    /// Aggregated access statistics (all on-chip memories + DRAM).
    pub fn stats(&self) -> &crate::trace::AccessStats {
        &self.result.stats
    }

    pub fn feasible(&self) -> bool {
        self.result.feasible()
    }

    pub fn active_utilization(&self) -> f64 {
        self.result.active_utilization()
    }

    pub fn e2e_utilization(&self) -> f64 {
        self.result.e2e_utilization()
    }

    /// Escape hatch: the raw `SimResult`. Its `traces` were **not**
    /// materialized — trace-derived queries on it return 0/empty.
    pub fn into_result(self) -> SimResult {
        self.result
    }
}

impl ExperimentSpec {
    /// Execute Stage I (build graph → simulate → energy breakdown).
    /// Serving specs have no single dataflow graph and are rejected here
    /// — run them via [`ExperimentSpec::run_serving`]
    /// (`api::serving`), which produces the merged KV-arena trace.
    pub fn run_stage1(&self, ctx: &ApiContext) -> Result<Stage1Run> {
        self.validate()?;
        let graph = build_workload(&self.model, self.workload)?;
        let result = simulate(&graph, &self.accel)?;
        let energy = energy_breakdown(&result, &self.accel, &ctx.cacti, &ctx.energy);
        Ok(Stage1Run {
            spec: self.clone(),
            graph,
            result,
            energy,
        })
    }

    /// Execute Stage I streaming occupancy into `sink` without
    /// materializing traces (O(1) trace memory).
    pub fn stream_stage1(
        &self,
        ctx: &ApiContext,
        sink: &mut dyn TraceSink,
    ) -> Result<Stage1Summary> {
        self.validate()?;
        let graph = build_workload(&self.model, self.workload)?;
        let result = simulate_with(
            &graph,
            &self.accel,
            SimOptions {
                sink: Some(sink),
                materialize: false,
            },
        )?;
        let energy = energy_breakdown(&result, &self.accel, &ctx.cacti, &ctx.energy);
        Ok(Stage1Summary {
            spec: self.clone(),
            graph,
            energy,
            result,
        })
    }

    /// Fused Stage I + Stage II: stream the simulation's shared-SRAM
    /// occupancy straight into the single-pass sweep engine
    /// ([`crate::banking::SweepSink`]) — Stage II finishes the moment
    /// Stage I does, with **no materialized trace**. Requires the spec to
    /// carry an explicit sweep grid: the streamed run has no trace to
    /// derive the paper grid's capacity floor from (grid capacities below
    /// the observed peak are still dropped, matching [`Stage1Run::stage2`]).
    /// Equivalent to `run_stage1` + `stage2_with` on the same grid,
    /// point for point.
    pub fn stream_stage2(
        &self,
        ctx: &ApiContext,
    ) -> Result<(Stage1Summary, Vec<SweepPoint>)> {
        let grid = self.sweep.as_ref().ok_or_else(|| {
            anyhow!(
                "stream_stage2 needs an explicit sweep grid on the spec \
                 (ExperimentSpecBuilder::sweep); a streamed run has no \
                 materialized trace to derive the paper grid from — use \
                 run_stage1 + stage2 for peak-derived grids"
            )
        })?;
        let mut sink = SweepSink::new(&ctx.cacti, grid, self.freq_ghz());
        let summary = self.stream_stage1(ctx, &mut sink)?;
        let points = sink.into_points(summary.stats());
        Ok((summary, points))
    }

    /// Fused Stage I + Stage III: stream the simulation's shared-SRAM
    /// occupancy straight into the online gating co-simulator
    /// ([`crate::banking::online::OnlineGateSim`]) — one chosen
    /// (C, B, α, policy) configuration replayed cycle by cycle with
    /// wake-latency stalls fed back into timing, **no materialized
    /// trace**. With `config.wake_override = Some(0)` the report's
    /// energy is bit-identical to the offline Stage-II evaluation of
    /// the same configuration.
    pub fn stream_online(
        &self,
        ctx: &ApiContext,
        config: OnlineConfig,
    ) -> Result<(Stage1Summary, OnlineReport)> {
        let mut sim = OnlineGateSim::new(&ctx.cacti, config, self.freq_ghz())?;
        let summary = self.stream_stage1(ctx, &mut sim)?;
        let report = sim.into_report(summary.stats())?;
        Ok((summary, report))
    }

    /// Stage-I memory sizing loop (16 MiB steps, CACTI latency model —
    /// the paper's §IV-B blue loop in Fig. 3).
    pub fn size_memory(&self, ctx: &ApiContext) -> Result<SizingResult> {
        self.validate()?;
        let graph = build_workload(&self.model, self.workload)?;
        let cacti = ctx.cacti.clone();
        size_memory(&graph, &self.accel, 16 * MIB, &move |cap| {
            cacti.latency_cycles(cap)
        })
    }
}

impl Stage1Run {
    /// Borrowed view of the shared-SRAM occupancy trace.
    pub fn trace(&self) -> &OccupancyTrace {
        self.result.sram_trace()
    }

    /// Borrowed views of every on-chip memory's trace (index 0 = shared).
    pub fn traces(&self) -> &[OccupancyTrace] {
        &self.result.traces
    }

    /// The paper's default Stage-II grid for this run (16 MiB capacity
    /// steps from the observed peak up to 128 MiB, B ∈ {1..32}, α = 0.9,
    /// aggressive gating).
    pub fn paper_sweep(&self) -> SweepSpec {
        SweepSpec::paper_grid(self.result.peak_needed())
    }

    /// The sweep grid this run will use: the spec's, or the derived
    /// paper grid when the spec left it open. With a hierarchy config
    /// the derived grid's capacity floor drops by the L2 pool size —
    /// spill candidates below the flat peak are exactly the points the
    /// hierarchy makes feasible.
    fn effective_sweep(&self) -> SweepSpec {
        self.spec.sweep.clone().unwrap_or_else(|| {
            let mut floor = self.result.peak_needed();
            if let Some(hc) = &self.spec.hierarchy {
                floor = floor.saturating_sub(hc.l2_capacity);
            }
            SweepSpec::paper_grid(floor)
        })
    }

    /// Stage II over the shared-SRAM trace with the run's aggregate
    /// access statistics (Table II semantics). Errors (instead of
    /// panicking) if the trace is unfinalized — possible only through
    /// direct mutation of `result`.
    pub fn stage2(&self, ctx: &ApiContext) -> Result<Stage2Run<'_>> {
        let spec = self.effective_sweep();
        self.stage2_with(ctx, &spec)
    }

    /// Stage II over the shared-SRAM trace with an explicit grid. When
    /// the spec carries a [`crate::banking::HierarchyConfig`] the sweep
    /// runs hierarchy-aware (banked L1 + L2 spill, migration and L2
    /// leakage folded into each point via
    /// [`crate::banking::HierarchyPoint::collapse`]); without one this
    /// is the flat engine, bit for bit.
    pub fn stage2_with(&self, ctx: &ApiContext, spec: &SweepSpec) -> Result<Stage2Run<'_>> {
        let trace = self.result.sram_trace();
        let points = match &self.spec.hierarchy {
            None => sweep(
                &ctx.cacti,
                trace,
                &self.result.stats,
                spec,
                self.spec.freq_ghz(),
            )?,
            Some(hc) => crate::banking::sweep_hierarchy(
                &ctx.cacti,
                trace,
                &self.result.stats,
                spec,
                self.spec.freq_ghz(),
                Some(hc),
            )?
            .into_iter()
            .map(crate::banking::HierarchyPoint::collapse)
            .collect(),
        };
        Ok(Stage2Run {
            stage1: self,
            spec: spec.clone(),
            per_memory: vec![(trace.memory.clone(), points)],
        })
    }

    /// Stage II independently per on-chip memory (Table III evaluates
    /// shared SRAM, DM1, DM2 separately). Traces zip *defensively* with
    /// their per-memory statistics: a length mismatch evaluates the
    /// common prefix instead of panicking.
    pub fn stage2_per_memory(&self, ctx: &ApiContext) -> Result<Stage2Run<'_>> {
        let spec = self.effective_sweep();
        self.stage2_per_memory_with(ctx, &spec)
    }

    /// Per-memory Stage II with an explicit grid.
    pub fn stage2_per_memory_with(
        &self,
        ctx: &ApiContext,
        spec: &SweepSpec,
    ) -> Result<Stage2Run<'_>> {
        let per_memory = self
            .result
            .traces
            .iter()
            .zip(self.result.per_mem_stats.iter())
            .map(|(tr, st)| {
                Ok((
                    tr.memory.clone(),
                    sweep(&ctx.cacti, tr, st, spec, self.spec.freq_ghz())?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Stage2Run {
            stage1: self,
            spec: spec.clone(),
            per_memory,
        })
    }
}

/// A materialized Stage-I run of either workload kind — the one place
/// that knows serving specs materialize via `run_serving` and
/// single-sequence specs via `run_stage1`. Consumers (the Stage-III
/// validation pass, its tests and bench) borrow the trace and
/// statistics instead of cloning them.
#[derive(Debug, Clone)]
pub enum MaterializedRun {
    Single(Stage1Run),
    Serving(ServingRun),
}

impl MaterializedRun {
    /// The run's primary occupancy trace (shared SRAM / KV arena).
    pub fn trace(&self) -> &OccupancyTrace {
        match self {
            MaterializedRun::Single(s) => s.trace(),
            MaterializedRun::Serving(r) => r.trace(),
        }
    }

    /// The run's aggregate access statistics (Eq. 3 inputs).
    pub fn stats(&self) -> &AccessStats {
        match self {
            MaterializedRun::Single(s) => &s.result.stats,
            MaterializedRun::Serving(r) => &r.result.stats,
        }
    }
}

impl ExperimentSpec {
    /// Materialize this spec's Stage-I run regardless of workload kind.
    pub fn materialize(&self, ctx: &ApiContext) -> Result<MaterializedRun> {
        match self.workload {
            Workload::Serving(_) => Ok(MaterializedRun::Serving(self.run_serving()?)),
            _ => Ok(MaterializedRun::Single(self.run_stage1(ctx)?)),
        }
    }
}

/// Stage-II output: sweep evaluations grouped per memory, borrowing the
/// Stage-I run they were derived from.
#[derive(Debug, Clone)]
pub struct Stage2Run<'a> {
    pub stage1: &'a Stage1Run,
    pub spec: SweepSpec,
    /// `(memory name, evaluated grid points)` — one entry for
    /// shared-SRAM sweeps, one per on-chip memory for per-memory sweeps.
    pub per_memory: Vec<(String, Vec<SweepPoint>)>,
}

impl Stage2Run<'_> {
    /// Points of the shared SRAM (first memory).
    pub fn shared(&self) -> &[SweepPoint] {
        self.per_memory
            .first()
            .map(|(_, pts)| pts.as_slice())
            .unwrap_or(&[])
    }

    /// All points across all memories.
    pub fn points(&self) -> impl Iterator<Item = &SweepPoint> + '_ {
        self.per_memory.iter().flat_map(|(_, pts)| pts.iter())
    }

    /// Lowest-energy candidate anywhere.
    pub fn best(&self) -> Option<&SweepPoint> {
        self.points()
            .min_by(|a, b| a.eval.e_total_j().total_cmp(&b.eval.e_total_j()))
    }

    /// Best ΔE% anywhere (the paper's headline metric; negative = win).
    pub fn best_delta_pct(&self) -> f64 {
        self.points()
            .map(|p| p.delta_e_pct())
            .fold(f64::INFINITY, f64::min)
    }

    /// Stage III: replay one configuration of this sweep online against
    /// the Stage-I trace the sweep was derived from — per-bank state
    /// machines, wake stalls delaying subsequent accesses, and a
    /// stall-adjusted end-to-end cycle count the offline sweep cannot
    /// produce. The configuration need not be a grid point; any
    /// [`OnlineConfig`] whose capacity covers the trace peak replays.
    pub fn replay_online(&self, ctx: &ApiContext, config: OnlineConfig) -> Result<OnlineReport> {
        Ok(replay_trace(
            &ctx.cacti,
            self.stage1.trace(),
            &self.stage1.result.stats,
            config,
            self.stage1.spec.freq_ghz(),
        )?)
    }

    /// Hierarchy-aware Stage III: like [`Stage2Run::replay_online`] but
    /// honoring the spec's [`crate::banking::HierarchyConfig`] — an L1
    /// capacity below the trace peak replays against the clamped trace
    /// with the L2 spill charged alongside. With no hierarchy on the
    /// spec (or a capacity covering the peak) the inner report is the
    /// flat replay bit for bit and `l2` is `None`.
    pub fn replay_online_hierarchy(
        &self,
        ctx: &ApiContext,
        config: OnlineConfig,
    ) -> Result<crate::banking::HierarchyReplay> {
        Ok(crate::banking::replay_hierarchy(
            &ctx.cacti,
            self.stage1.trace(),
            &self.stage1.result.stats,
            config,
            self.stage1.spec.freq_ghz(),
            true,
            self.stage1.spec.hierarchy.as_ref(),
        )?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::GatingPolicy;
    use crate::config::{multilevel, tiny};
    use crate::workload::TINY_GQA;

    fn small_grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB],
            banks: vec![1, 4, 8],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        }
    }

    fn tiny_spec() -> ExperimentSpec {
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .sweep(small_grid())
            .build()
            .unwrap()
    }

    #[test]
    fn stage1_then_stage2_composes() {
        let ctx = ApiContext::new();
        let s1 = tiny_spec().run_stage1(&ctx).unwrap();
        assert!(s1.result.feasible());
        assert!(s1.energy.total_j() > 0.0);
        let s2 = s1.stage2(&ctx).unwrap();
        assert!(!s2.shared().is_empty());
        // Gating must find idle intervals and cut leakage vs B=1.
        let best = s2
            .points()
            .filter(|p| p.eval.banks > 1)
            .min_by(|a, b| a.eval.e_leak_j.total_cmp(&b.eval.e_leak_j))
            .unwrap();
        let base = s2.points().find(|p| p.eval.banks == 1).unwrap();
        assert!(best.eval.gated_fraction > 0.0, "no idle intervals found");
        assert!(best.eval.e_leak_j < base.eval.e_leak_j);
    }

    #[test]
    fn stage2_matches_direct_sweep() {
        // The handle path must be numerically identical to calling the
        // Stage-II evaluator directly (what Coordinator::stage2 did).
        let ctx = ApiContext::new();
        let s1 = tiny_spec().run_stage1(&ctx).unwrap();
        let direct = sweep(
            &ctx.cacti,
            s1.result.sram_trace(),
            &s1.result.stats,
            &small_grid(),
            s1.spec.freq_ghz(),
        ).unwrap();
        let s2 = s1.stage2(&ctx).unwrap();
        assert_eq!(s2.shared().len(), direct.len());
        for (a, b) in s2.shared().iter().zip(&direct) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
        }
    }

    #[test]
    fn stage2_per_memory_zips_defensively() {
        let ctx = ApiContext::new();
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(multilevel())
            .sweep(small_grid())
            .build()
            .unwrap();
        let mut s1 = spec.run_stage1(&ctx).unwrap();
        assert_eq!(s1.result.traces.len(), 3);
        let full = s1.stage2_per_memory(&ctx).unwrap();
        assert_eq!(full.per_memory.len(), 3);

        // Divergent lengths (e.g. a deserialized result missing stats)
        // must evaluate the common prefix, not panic.
        s1.result.per_mem_stats.truncate(1);
        let partial = s1.stage2_per_memory(&ctx).unwrap();
        assert_eq!(partial.per_memory.len(), 1);
        assert_eq!(partial.per_memory[0].0, "sram");
    }

    #[test]
    fn streaming_summary_matches_materialized_run() {
        use crate::trace::OnlineStatsSink;
        let ctx = ApiContext::new();
        let spec = tiny_spec();
        let s1 = spec.run_stage1(&ctx).unwrap();
        let mut stats = OnlineStatsSink::new();
        let summary = spec.stream_stage1(&ctx, &mut stats).unwrap();
        assert_eq!(summary.total_cycles(), s1.result.total_cycles);
        assert_eq!(summary.stats(), &s1.result.stats);
        assert!(summary.feasible());
        // The online sink observed the real peak...
        assert_eq!(
            stats.shared().unwrap().peak_needed(),
            s1.result.peak_needed()
        );
        // ...while the raw result's traces were never materialized
        // (escape hatch documents this).
        assert_eq!(summary.into_result().sram_trace().samples().len(), 1);
    }

    #[test]
    fn stream_stage2_matches_materialized_pipeline() {
        let ctx = ApiContext::new();
        let spec = tiny_spec();
        let s1 = spec.run_stage1(&ctx).unwrap();
        let reference = s1.stage2_with(&ctx, &small_grid()).unwrap();
        let (summary, points) = spec.stream_stage2(&ctx).unwrap();
        assert_eq!(summary.total_cycles(), s1.result.total_cycles);
        assert_eq!(summary.stats(), &s1.result.stats);
        assert_eq!(points.len(), reference.shared().len());
        for (a, b) in points.iter().zip(reference.shared()) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
            assert_eq!(a.eval.policy, b.eval.policy);
            assert_eq!(a.base_e_j.to_bits(), b.base_e_j.to_bits());
        }
        // The streamed result never materialized a trace.
        assert_eq!(summary.into_result().sram_trace().samples().len(), 1);
    }

    #[test]
    fn stream_stage2_requires_explicit_grid() {
        let ctx = ApiContext::new();
        let mut bare = tiny_spec();
        bare.sweep = None;
        let err = bare.stream_stage2(&ctx).unwrap_err();
        assert!(err.to_string().contains("sweep grid"), "{err:#}");
    }

    #[test]
    fn stream_online_matches_materialized_replay() {
        use crate::banking::{GatingPolicy, OnlineConfig};
        let ctx = ApiContext::new();
        let spec = tiny_spec();
        let s1 = spec.run_stage1(&ctx).unwrap();
        let cfg = OnlineConfig::new(
            4 * MIB,
            8,
            0.9,
            GatingPolicy::Aggressive,
        );
        let reference = s1
            .stage2(&ctx)
            .unwrap()
            .replay_online(&ctx, cfg)
            .unwrap();
        let (summary, streamed) = spec.stream_online(&ctx, cfg).unwrap();
        assert_eq!(summary.total_cycles(), s1.result.total_cycles);
        assert_eq!(streamed.trace_cycles, s1.result.total_cycles);
        assert_eq!(streamed.stall_cycles, reference.stall_cycles);
        assert_eq!(
            streamed.eval.e_total_j().to_bits(),
            reference.eval.e_total_j().to_bits()
        );
        assert_eq!(streamed.timelines, reference.timelines);
    }

    #[test]
    fn hierarchy_spec_stage2_matches_flat_above_peak_and_admits_spill() {
        use crate::banking::HierarchyConfig;
        let ctx = ApiContext::new();
        let flat = tiny_spec().run_stage1(&ctx).unwrap();
        let flat_s2 = flat.stage2(&ctx).unwrap();

        let mut spec = tiny_spec();
        spec.hierarchy = Some(HierarchyConfig::new(4 * MIB));
        let run = spec.run_stage1(&ctx).unwrap();
        let hier_s2 = run.stage2(&ctx).unwrap();
        let peak = run.result.peak_needed();

        // Flat-feasible capacities reappear bit-identically (the flat
        // engine only ever emits capacities >= peak).
        let covering: Vec<_> = hier_s2
            .shared()
            .iter()
            .filter(|p| p.eval.capacity >= peak)
            .collect();
        assert_eq!(flat_s2.shared().len(), covering.len());
        for (a, b) in flat_s2.shared().iter().zip(&covering) {
            assert_eq!(
                a.eval.e_total_j().to_bits(),
                b.eval.e_total_j().to_bits()
            );
        }
        // The hierarchy can only add (spill) candidates, never drop any.
        assert!(hier_s2.shared().len() >= flat_s2.shared().len());
    }

    #[test]
    fn sizing_composes_with_cacti_latency() {
        let ctx = ApiContext::new();
        let r = tiny_spec().size_memory(&ctx).unwrap();
        assert!(r.verify.feasible());
        assert_eq!(r.required_capacity % (16 * MIB), 0);
    }
}
