//! WAL-logged materialization: run a spec with a
//! [`crate::obs::WalSink`] teed into the simulation so every occupancy
//! sample and run event lands in an on-disk event log *while* the
//! materialized traces are built in memory.
//!
//! The two outputs are redundant by construction — replaying the WAL
//! ([`crate::obs::replay_wal`]) reconstructs the materialized
//! [`crate::trace::OccupancyTrace`]s bit-identically, because the
//! replayer issues the exact `record()` calls the materializing sink
//! saw. That redundancy is the point: an interrupted run leaves a WAL
//! prefix that `repro watch` can render and the lab executor can
//! resume from, and a completed run's WAL is a self-contained,
//! deterministic artifact (`run_id` = spec content hash, wall clock
//! only in the segment header).

use std::path::Path;

use anyhow::{Context, Result};

use crate::energy::energy_breakdown;
use crate::obs::WalSink;
use crate::sim::serving::{simulate_serving_with, ServingSimOptions};
use crate::sim::{simulate_with, SimOptions};
use crate::workload::{build_workload, Workload};

use super::serving::ServingRun;
use super::spec::ExperimentSpec;
use super::stage::{ApiContext, MaterializedRun, Stage1Run};

impl ExperimentSpec {
    /// [`ExperimentSpec::materialize`] with a write-ahead event log:
    /// identical results (same traces, same stats, same energy), plus a
    /// complete WAL under `wal_dir` whose `run_id` is this spec's
    /// [`ExperimentSpec::content_hash`]. Pass `wall_unix_ms = 0` for
    /// byte-deterministic logs (the wall clock appears only in segment
    /// headers); pass the real clock when human-readable provenance
    /// matters more than `diff`-ability.
    pub fn materialize_logged(
        &self,
        ctx: &ApiContext,
        wal_dir: &Path,
        wall_unix_ms: u64,
    ) -> Result<MaterializedRun> {
        self.validate()?;
        let run_id = self.content_hash();
        let mut wal = WalSink::create(wal_dir, run_id, wall_unix_ms)
            .with_context(|| format!("creating WAL at {}", wal_dir.display()))?;
        let run = match self.workload {
            Workload::Serving(params) => {
                let result = simulate_serving_with(
                    &self.model,
                    params,
                    &self.accel,
                    ServingSimOptions {
                        sink: Some(&mut wal),
                        materialize: true,
                    },
                )?;
                MaterializedRun::Serving(ServingRun {
                    spec: self.clone(),
                    result,
                })
            }
            _ => {
                let graph = build_workload(&self.model, self.workload)?;
                let result = simulate_with(
                    &graph,
                    &self.accel,
                    SimOptions {
                        sink: Some(&mut wal),
                        materialize: true,
                    },
                )?;
                let energy =
                    energy_breakdown(&result, &self.accel, &ctx.cacti, &ctx.energy);
                MaterializedRun::Single(Stage1Run {
                    spec: self.clone(),
                    graph,
                    result,
                    energy,
                })
            }
        };
        wal.close(Some(run.stats()))
            .with_context(|| format!("sealing WAL at {}", wal_dir.display()))?;
        Ok(run)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::tiny;
    use crate::obs::replay_wal;
    use crate::serving::ServingParams;
    use crate::workload::TINY_GQA;

    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-observe-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn assert_traces_match(
        got: &[crate::trace::OccupancyTrace],
        want: &[crate::trace::OccupancyTrace],
    ) {
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.memory, w.memory);
            assert_eq!(g.capacity, w.capacity);
            assert_eq!(g.samples(), w.samples());
            assert_eq!(g.end_time(), w.end_time());
            assert_eq!(g.avg_needed().to_bits(), w.avg_needed().to_bits());
        }
    }

    #[test]
    fn logged_single_run_matches_plain_and_replays() {
        let ctx = ApiContext::new();
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap();
        let dir = tmp_dir("single");

        let plain = spec.materialize(&ctx).unwrap();
        let logged = spec.materialize_logged(&ctx, &dir, 0).unwrap();
        assert_eq!(logged.trace().samples(), plain.trace().samples());
        assert_eq!(logged.stats(), plain.stats());

        let replay = replay_wal(&dir).unwrap();
        assert!(replay.complete);
        assert_eq!(replay.run_id, spec.content_hash());
        let MaterializedRun::Single(s) = &logged else {
            panic!("prefill spec materialized as serving");
        };
        assert_traces_match(&replay.traces, &s.result.traces);
        assert_eq!(replay.stats.as_ref(), Some(plain.stats()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn logged_serving_run_matches_plain_and_replays() {
        let ctx = ApiContext::new();
        let mut p = ServingParams::new(12, 3, 7);
        p.prompt_min = 4;
        p.prompt_max = 24;
        p.gen_min = 2;
        p.gen_max = 12;
        p.page_tokens = 8;
        p.mean_arrival_gap = 40_000;
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(tiny())
            .build()
            .unwrap();
        let dir = tmp_dir("serving");

        let plain = spec.materialize(&ctx).unwrap();
        let logged = spec.materialize_logged(&ctx, &dir, 0).unwrap();
        assert_eq!(logged.trace().samples(), plain.trace().samples());
        assert_eq!(logged.stats(), plain.stats());

        let replay = replay_wal(&dir).unwrap();
        assert!(replay.complete);
        assert_eq!(replay.run_id, spec.content_hash());
        assert_traces_match(
            &replay.traces,
            std::slice::from_ref(logged.trace()),
        );
        assert_eq!(replay.stats.as_ref(), Some(plain.stats()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rerun_resets_the_log_instead_of_appending() {
        let ctx = ApiContext::new();
        let spec = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(32)
            .accel(tiny())
            .build()
            .unwrap();
        let dir = tmp_dir("rerun");
        spec.materialize_logged(&ctx, &dir, 0).unwrap();
        let first = replay_wal(&dir).unwrap();
        spec.materialize_logged(&ctx, &dir, 0).unwrap();
        let second = replay_wal(&dir).unwrap();
        assert!(second.complete);
        assert_traces_match(&second.traces, &first.traces);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
