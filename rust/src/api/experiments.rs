//! Per-experiment runners: one function per table/figure of the paper,
//! built on the typed `trapti::api` pipeline.
//!
//! Both the `repro report <exp>` CLI and the benches call these, so the
//! numbers in reports and benches can never diverge. Each returns a
//! structured result that `report::{tables,figures}` renders. The
//! paired prefill (the workhorse behind Figs. 5–9 and Table II) runs
//! its two Stage-I simulations as one parallel batch.

use std::sync::Arc;

use anyhow::Result;

use crate::analytic::estimate_pim;
use crate::banking::{
    bank_activity, ActivitySegment, GatingPolicy, HierarchyConfig, OccupancyBasis,
    SweepPoint, SweepSpec,
};
use crate::config::{baseline, multilevel, AccelConfig};
use crate::serving::ServingParams;
use crate::util::MIB;
use crate::workload::{AttnKind, ModelPreset, DS_R1D_Q15B, GPT2_XL};

use super::batch::BatchRunner;
use super::spec::ExperimentSpec;
use super::stage::{ApiContext, Stage1Run};

/// The paper's sequence length (§IV-A).
pub const PAPER_SEQ: u32 = 2048;
/// Decode setting for the Fig. 1 motivation (prompt + generated tokens).
pub const FIG1_PROMPT: u32 = 512;
pub const FIG1_GEN: u32 = 128;

/// Fig. 1 — MHA vs GQA normalized energy and latency in decode.
///
/// Two views: the *whole-model* decode (which on this template is
/// weight-restreaming-bound, compressing the MHA/GQA gap) and the
/// *attention subsystem* (score/softmax/context/KV traffic), which is
/// what GQA actually changes and matches the paper's 2.89x/3.14x regime.
pub struct Fig1 {
    pub mha_energy_j: f64,
    pub gqa_energy_j: f64,
    pub mha_seconds: f64,
    pub gqa_seconds: f64,
    /// Attention-subsystem elapsed cycles (compute + memory).
    pub mha_attn_cycles: u64,
    pub gqa_attn_cycles: u64,
    /// Attention-subsystem energy (traffic + MACs + time-share leakage).
    pub mha_attn_energy_j: f64,
    pub gqa_attn_energy_j: f64,
}

impl Fig1 {
    /// Whole-model ratios.
    pub fn energy_ratio(&self) -> f64 {
        self.mha_energy_j / self.gqa_energy_j
    }

    pub fn latency_ratio(&self) -> f64 {
        self.mha_seconds / self.gqa_seconds
    }

    /// Attention-subsystem ratios (paper: 2.89x energy, 3.14x latency).
    pub fn attn_energy_ratio(&self) -> f64 {
        self.mha_attn_energy_j / self.gqa_attn_energy_j
    }

    pub fn attn_latency_ratio(&self) -> f64 {
        self.mha_attn_cycles as f64 / self.gqa_attn_cycles as f64
    }
}

fn attention_view(s1: &Stage1Run) -> (u64, f64) {
    use crate::workload::OpClass;
    let attn_classes = [
        OpClass::AttnScore,
        OpClass::AttnSoftmax,
        OpClass::AttnContext,
        OpClass::KvAppend,
    ];
    let cycles: u64 = attn_classes
        .iter()
        .filter_map(|c| s1.result.op_breakdown.get(c))
        .map(|b| b.compute + b.memory)
        .sum();
    // Attention traffic & MACs from the graph; energy apportioned from
    // the Fig. 7 components by share.
    let (mut attn_stream, mut total_stream) = (0u64, 0u64);
    let (mut attn_macs, mut total_macs) = (0u64, 0u64);
    for op in &s1.graph.ops {
        let b = op.kind.streamed_bytes();
        let m = op.macs();
        total_stream += b;
        total_macs += m;
        if attn_classes.contains(&OpClass::of(op)) {
            attn_stream += b;
            attn_macs += m;
        }
    }
    let stream_share = attn_stream as f64 / total_stream.max(1) as f64;
    let mac_share = attn_macs as f64 / total_macs.max(1) as f64;
    let time_share = cycles as f64 / (s1.result.total_cycles.max(1) as f64);
    let e = s1.energy.sram_dynamic_j * stream_share
        + s1.energy.pe_dynamic_j * mac_share
        + (s1.energy.sram_leakage_j + s1.energy.pe_static_j + s1.energy.fifo_static_j)
            * time_share;
    (cycles, e)
}

/// Run two specs as one parallel batch and hand back owned Stage-I runs.
fn run_pair(
    ctx: &ApiContext,
    a: ExperimentSpec,
    b: ExperimentSpec,
) -> Result<(Stage1Run, Stage1Run)> {
    let out = BatchRunner::with_context(ctx.clone()).run(&[a, b])?;
    let mut it = out.into_iter();
    let first = it.next().expect("batch preserves arity").stage1;
    let second = it.next().expect("batch preserves arity").stage1;
    let unwrap = |arc: Arc<Stage1Run>| {
        Arc::try_unwrap(arc).unwrap_or_else(|shared| (*shared).clone())
    };
    Ok((unwrap(first), unwrap(second)))
}

pub fn fig1(ctx: &ApiContext) -> Result<Fig1> {
    // Matched ~85M-parameter pair with SRAM-resident weights: the
    // regime where decode cost is attention/KV-bound (see FIG1_* docs).
    let mut accel = baseline();
    accel.sched.weight_resident = true;
    let spec_for = |model| {
        ExperimentSpec::builder()
            .model(model)
            .decode(FIG1_PROMPT, FIG1_GEN)
            .accel(accel.clone())
            .build()
    };
    let (mha, gqa) = run_pair(
        ctx,
        spec_for(crate::workload::models::FIG1_MHA)?,
        spec_for(crate::workload::models::FIG1_GQA)?,
    )?;
    let (mha_ac, mha_ae) = attention_view(&mha);
    let (gqa_ac, gqa_ae) = attention_view(&gqa);
    Ok(Fig1 {
        mha_energy_j: mha.energy.on_chip_j(),
        gqa_energy_j: gqa.energy.on_chip_j(),
        mha_seconds: mha.result.seconds(),
        gqa_seconds: gqa.result.seconds(),
        mha_attn_cycles: mha_ac,
        gqa_attn_cycles: gqa_ac,
        mha_attn_energy_j: mha_ae,
        gqa_attn_energy_j: gqa_ae,
    })
}

/// Fig. 5 + Fig. 6 + Fig. 7 all come from the same two Stage-I runs
/// (both workloads, prefill 2048, 128 MiB shared SRAM).
pub struct PairedStage1 {
    pub mha: Stage1Run,
    pub gqa: Stage1Run,
    pub accel: AccelConfig,
}

pub fn paired_prefill(ctx: &ApiContext) -> Result<PairedStage1> {
    let accel = baseline();
    let spec_for = |model| {
        ExperimentSpec::builder()
            .model(model)
            .prefill(PAPER_SEQ)
            .accel(accel.clone())
            .build()
    };
    let (mha, gqa) = run_pair(ctx, spec_for(GPT2_XL)?, spec_for(DS_R1D_Q15B)?)?;
    Ok(PairedStage1 { mha, gqa, accel })
}

impl PairedStage1 {
    /// The paper's headline peak-utilization ratio (2.72x).
    pub fn peak_ratio(&self) -> f64 {
        self.mha.result.peak_needed() as f64 / self.gqa.result.peak_needed() as f64
    }

    /// End-to-end time ratio (paper: 593.9/313.6 = 1.89x).
    pub fn time_ratio(&self) -> f64 {
        self.mha.result.seconds() / self.gqa.result.seconds()
    }
}

/// §IV-B sizing results for both workloads (peak -> 16 MiB-step capacity)
/// plus the DS 64 MiB latency-delta check.
pub struct Sizing {
    pub mha_peak: u64,
    pub mha_required: u64,
    pub gqa_peak: u64,
    pub gqa_required: u64,
    /// DS at 64 MiB vs 128 MiB: latency delta seconds (paper: -1.48 ms,
    /// from the faster 22 ns SRAM).
    pub gqa_64mib_delta_s: f64,
}

pub fn sizing(ctx: &ApiContext) -> Result<Sizing> {
    let accel = baseline();
    let spec_for = |model, accel: &AccelConfig| {
        ExperimentSpec::builder()
            .model(model)
            .prefill(PAPER_SEQ)
            .accel(accel.clone())
            .build()
    };
    let mha = spec_for(GPT2_XL, &accel)?.size_memory(ctx)?;
    let gqa = spec_for(DS_R1D_Q15B, &accel)?.size_memory(ctx)?;
    let accel_64 =
        accel.with_sram_capacity(64 * MIB, ctx.cacti.latency_cycles(64 * MIB));
    let (gqa_128, gqa_64) = run_pair(
        ctx,
        spec_for(DS_R1D_Q15B, &accel)?,
        spec_for(DS_R1D_Q15B, &accel_64)?,
    )?;
    Ok(Sizing {
        mha_peak: mha.peak_needed,
        mha_required: mha.required_capacity,
        gqa_peak: gqa.peak_needed,
        gqa_required: gqa.required_capacity,
        gqa_64mib_delta_s: gqa_64.result.seconds() - gqa_128.result.seconds(),
    })
}

/// Fig. 8 — bank activity timeline for DS at 64 MiB, B=4, several alphas.
pub struct Fig8 {
    pub alphas: Vec<f64>,
    pub timelines: Vec<Vec<ActivitySegment>>,
    pub trace_peak: u64,
}

pub fn fig8(gqa: &Stage1Run) -> Fig8 {
    let alphas = vec![1.0, 0.9, 0.75, 0.5];
    let trace = gqa.trace();
    let timelines = alphas
        .iter()
        .map(|&a| bank_activity(trace, 64 * MIB, 4, a, OccupancyBasis::NeededOnly))
        .collect();
    Fig8 {
        alphas,
        timelines,
        trace_peak: trace.peak_needed(),
    }
}

/// Table II — banking sweeps for both workloads at alpha = 0.9.
pub struct Table2 {
    pub gqa_points: Vec<SweepPoint>,
    pub mha_points: Vec<SweepPoint>,
}

pub fn table2(ctx: &ApiContext, pair: &PairedStage1) -> Result<Table2> {
    Ok(Table2 {
        gqa_points: pair.gqa.stage2(ctx)?.shared().to_vec(),
        mha_points: pair.mha.stage2(ctx)?.shared().to_vec(),
    })
}

impl Table2 {
    /// Best ΔE% anywhere (the paper's "up to 78%" headline is the best
    /// Table III cell; Table II's best is DS 128 MiB B=16 at -61.3%).
    pub fn best_delta(&self) -> f64 {
        self.gqa_points
            .iter()
            .chain(&self.mha_points)
            .map(|p| p.delta_e_pct())
            .fold(f64::INFINITY, f64::min)
    }

    /// Best bank count per capacity for a workload's points.
    pub fn best_banks_at(points: &[SweepPoint], capacity: u64) -> Option<u32> {
        points
            .iter()
            .filter(|p| p.eval.capacity == capacity)
            .min_by(|a, b| a.eval.e_total_j().total_cmp(&b.eval.e_total_j()))
            .map(|p| p.eval.banks)
    }
}

/// Table III / §IV-D — multi-level hierarchy run + per-memory sweeps.
pub struct Table3 {
    pub stage1: Stage1Run,
    /// (memory name, sweep points at {48, 64} MiB).
    pub per_memory: Vec<(String, Vec<SweepPoint>)>,
}

pub fn table3(ctx: &ApiContext) -> Result<Table3> {
    let spec = ExperimentSpec::builder()
        .model(DS_R1D_Q15B)
        .prefill(PAPER_SEQ)
        .accel(multilevel())
        .sweep(SweepSpec {
            capacities: vec![48 * MIB, 64 * MIB],
            banks: vec![1, 4, 8, 16],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        })
        .build()?;
    let stage1 = spec.run_stage1(ctx)?;
    let per_memory = stage1.stage2_per_memory(ctx)?.per_memory;
    Ok(Table3 { stage1, per_memory })
}

impl Table3 {
    /// Best ΔE% across all memories — the paper's 78% headline
    /// (shared SRAM, 64 MiB, B=16: -77.8%).
    pub fn best_delta(&self) -> f64 {
        self.per_memory
            .iter()
            .flat_map(|(_, pts)| pts.iter().map(|p| p.delta_e_pct()))
            .fold(f64::INFINITY, f64::min)
    }
}

/// Fig. 10 — serving occupancy vs concurrency: one multi-tenant serving
/// run per concurrency level, each swept through Stage II.
pub struct Fig10Point {
    pub concurrency: u32,
    pub peak_needed: u64,
    pub peak_occupied: u64,
    pub avg_needed: f64,
    pub total_cycles: u64,
    pub completed: u32,
    pub peak_concurrent: u32,
    /// Best Stage-II candidate on this trace.
    pub best_banks: u32,
    pub best_policy: GatingPolicy,
    pub best_capacity: u64,
    pub best_delta_pct: f64,
}

/// Concurrency axis of the serving figure.
pub const FIG10_CONCURRENCY: [u32; 4] = [1, 4, 16, 64];

/// Run the serving scenario at each concurrency in
/// [`FIG10_CONCURRENCY`] (same request population and seed throughout)
/// and sweep each merged trace through Stage II.
pub fn fig10_serving(
    ctx: &ApiContext,
    model: &ModelPreset,
    requests: u32,
    seed: u64,
) -> Result<Vec<Fig10Point>> {
    FIG10_CONCURRENCY
        .iter()
        .map(|&concurrency| {
            let spec = ExperimentSpec::builder()
                .model(model.clone())
                .serving(ServingParams::new(requests, concurrency, seed))
                .build()?;
            let run = spec.run_serving()?;
            let s2 = run.stage2(ctx)?;
            let best = s2
                .best()
                .expect("serving grid is never empty");
            Ok(Fig10Point {
                concurrency,
                peak_needed: run.trace().peak_needed(),
                peak_occupied: run.trace().peak_occupied(),
                avg_needed: run.trace().avg_needed(),
                total_cycles: run.result.total_cycles,
                completed: run.result.completed,
                peak_concurrent: run.result.peak_concurrent,
                best_banks: best.eval.banks,
                best_policy: best.eval.policy,
                best_capacity: best.eval.capacity,
                best_delta_pct: best.delta_e_pct(),
            })
        })
        .collect()
}

/// One attention variant's row of the `repro spectrum` report: the
/// whole pipeline (Stage I decode → Stage II sweep → best gated point)
/// plus the PIM-offload comparison column.
pub struct SpectrumRow {
    pub name: &'static str,
    pub attn: AttnKind,
    /// KV-cache footprint at the final context (window/latent aware).
    pub kv_bytes: u64,
    /// Stage-I peak needed bytes — the monotone curve's y-axis.
    pub peak_needed: u64,
    /// Best Stage-II ΔE% on this variant's trace.
    pub best_delta_pct: f64,
    /// Best gated candidate's total energy, joules.
    pub best_energy_j: f64,
    /// PIM-offload closed form for the same workload.
    pub pim_e_j: f64,
    /// SRAM peak with the KV offloaded to the arrays.
    pub pim_relieved_peak: u64,
}

/// The attention-variant spectrum (`repro spectrum`): MHA → GQA → MQA →
/// MLA at matched parameter count, plus the sliding-window plateau
/// point, each run through the full Stage I→II pipeline.
pub struct Spectrum {
    pub prompt: u32,
    pub gen: u32,
    pub rows: Vec<SpectrumRow>,
    /// The paper's two-point headline (GPT-2 XL / ds-r1d peak ratio,
    /// 2.72x) for context next to the curve; `None` when the
    /// paper-scale pair was skipped.
    pub paper_peak_ratio: Option<f64>,
}

impl Spectrum {
    /// The tentpole invariant: peak occupancy is monotone non-increasing
    /// across MHA → GQA → MQA → MLA (the SWA plateau row is excluded —
    /// it trades horizon, not per-token width).
    pub fn peak_is_monotone(&self) -> bool {
        let chain: Vec<_> = self.rows.iter().take(4).collect();
        chain.windows(2).all(|w| w[0].peak_needed >= w[1].peak_needed)
    }
}

/// Run the spectrum: every [`crate::workload::spectrum_presets`] variant
/// decodes `prompt`+`gen` tokens on the weight-resident baseline (the
/// Fig. 1 regime, where decode occupancy is KV-bound), then sweeps its
/// trace through Stage II — hierarchy-aware when `hierarchy` is set.
/// `with_paper_ratio` additionally runs the paper-scale prefill pair for
/// the 2.72x context line (minutes of work at full scale).
pub fn spectrum(
    ctx: &ApiContext,
    prompt: u32,
    gen: u32,
    hierarchy: Option<HierarchyConfig>,
    with_paper_ratio: bool,
) -> Result<Spectrum> {
    let mut accel = baseline();
    accel.sched.weight_resident = true;
    let specs = crate::workload::spectrum_presets()
        .into_iter()
        .map(|m| {
            let mut b = ExperimentSpec::builder()
                .model(m)
                .decode(prompt, gen)
                .accel(accel.clone());
            if let Some(hc) = hierarchy {
                b = b.hierarchy(hc);
            }
            b.build()
        })
        .collect::<Result<Vec<_>>>()?;
    let runs = BatchRunner::with_context(ctx.clone()).run(&specs)?;
    let mut rows = Vec::with_capacity(runs.len());
    for out in runs {
        let s1 = out.stage1;
        let s2 = s1.stage2(ctx)?;
        let best = s2.best().expect("derived grid is never empty");
        let pim = estimate_pim(&s1.spec.model, &s1.spec.workload)
            .expect("decode always has a PIM closed form");
        let peak = s1.result.peak_needed();
        rows.push(SpectrumRow {
            name: s1.spec.model.name,
            attn: s1.spec.model.attn_kind(),
            kv_bytes: s1.spec.model.kv_cache_bytes(prompt as u64 + gen as u64),
            peak_needed: peak,
            best_delta_pct: best.delta_e_pct(),
            best_energy_j: best.eval.e_total_j(),
            pim_e_j: pim.e_pim_j,
            pim_relieved_peak: pim.relieved_peak(peak),
        });
    }
    let paper_peak_ratio = if with_paper_ratio {
        Some(paired_prefill(ctx)?.peak_ratio())
    } else {
        None
    };
    Ok(Spectrum {
        prompt,
        gen,
        rows,
        paper_peak_ratio,
    })
}

/// Headline numbers pulled together for `repro report headline`.
pub struct Headline {
    pub peak_ratio: f64,
    pub time_ratio: f64,
    pub table2_best_delta: f64,
    pub table3_best_delta: f64,
    /// GQA's best ΔE minus MHA's best ΔE (paper: GQA benefits ~20% more).
    pub gqa_extra_benefit_pct: f64,
}

pub fn headline(ctx: &ApiContext) -> Result<Headline> {
    let pair = paired_prefill(ctx)?;
    let t2 = table2(ctx, &pair)?;
    let t3 = table3(ctx)?;
    let gqa_best = t2
        .gqa_points
        .iter()
        .map(|p| p.delta_e_pct())
        .fold(f64::INFINITY, f64::min);
    let mha_best = t2
        .mha_points
        .iter()
        .map(|p| p.delta_e_pct())
        .fold(f64::INFINITY, f64::min);
    Ok(Headline {
        peak_ratio: pair.peak_ratio(),
        time_ratio: pair.time_ratio(),
        table2_best_delta: t2.best_delta(),
        table3_best_delta: t3.best_delta(),
        gqa_extra_benefit_pct: mha_best - gqa_best,
    })
}

/// Built-in lab manifests (`repro lab run --manifest @<name>`): the
/// figure/table batch runners expressed as declarative
/// [`crate::lab::LabManifest`] TOML, so the standing experiments flow
/// through the same content-addressed store as ad-hoc ones.
///
/// * `@paper` — the headline portfolio: both paper models × decode and
///   serving × a Table-II-shaped grid (capacities sized so the serving
///   arena fits and the portfolio is non-empty).
/// * `@paired-prefill` — the Figs. 5–9 / Table II workhorse pair at the
///   paper sequence length, grid derived from the Stage-I peaks.
/// * `@tiny` — a seconds-scale smoke manifest (the CI determinism gate
///   runs it; mirrors `rust/configs/lab_tiny.toml`).
pub fn lab_manifest(name: &str) -> Option<&'static str> {
    // NOTE: the TOML-subset parser reads arrays on a single line only.
    match name {
        "paper" => Some(
            r#"[lab]
name = "paper"
accel = "baseline"
workloads = ["gpt2-xl:decode:512:128", "ds-r1d:decode:512:128", "gpt2-xl:serve:64:8:7", "ds-r1d:serve:64:8:7"]
# Stage-III replay of every frontier config across four workloads is
# minutes of work; flip on for the full validation sweep.
validate = false

[grid]
capacities = ["128MiB", "256MiB", "512MiB", "768MiB"]
banks = [1, 2, 4, 8, 16, 32]
alphas = [0.9]
policies = ["none", "aggressive", "conservative", "drowsy"]
"#,
        ),
        "paired-prefill" => Some(
            r#"[lab]
name = "paired-prefill"
accel = "baseline"
workloads = ["gpt2-xl:prefill:2048", "ds-r1d:prefill:2048"]
validate = false
# No [grid]: derive the covering grid from the Stage-I peaks.
"#,
        ),
        "tiny" => Some(
            r#"[lab]
name = "tiny"
accel = "tiny"
workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8", "tiny-gqa:serve:8:2:7"]
validate = true

[grid]
capacities = ["2MiB", "4MiB"]
banks = [1, 2, 4, 8]
alphas = [0.9]
policies = ["aggressive", "drowsy"]
"#,
        ),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full-scale experiment tests live in rust/tests/paper_experiments.rs
    // (release-mode integration); here we only pin cheap invariants.

    #[test]
    fn constants_match_paper() {
        assert_eq!(PAPER_SEQ, 2048);
    }

    #[test]
    fn fig10_runs_at_each_concurrency() {
        let ctx = ApiContext::new();
        let pts = fig10_serving(&ctx, &crate::workload::TINY_GQA, 8, 1).unwrap();
        assert_eq!(pts.len(), FIG10_CONCURRENCY.len());
        for (p, &c) in pts.iter().zip(&FIG10_CONCURRENCY) {
            assert_eq!(p.concurrency, c);
            assert_eq!(p.completed, 8);
            assert!(p.peak_concurrent >= 1 && p.peak_concurrent <= c.min(8));
            assert!(p.peak_needed > 0);
            assert!(p.best_banks >= 1);
        }
    }

    #[test]
    fn spectrum_rows_cover_every_variant_and_stay_monotone() {
        // Short decode keeps this in unit-test time; the KV ordering
        // dominates peak occupancy even at small contexts because the
        // presets are parameter-matched (weights identical in size).
        let ctx = ApiContext::new();
        let s = spectrum(&ctx, 64, 4, None, false).unwrap();
        assert_eq!(s.rows.len(), crate::workload::spectrum_presets().len());
        assert_eq!(s.rows[0].name, "fig1-mha-124m");
        assert!(s.paper_peak_ratio.is_none());
        assert!(s.peak_is_monotone(), "MHA>=GQA>=MQA>=MLA peak ordering");
        for r in &s.rows {
            assert!(r.peak_needed > 0);
            assert!(r.kv_bytes > 0);
            assert!(r.best_delta_pct <= 0.0, "{}: gating never hurts", r.name);
            assert!(r.pim_e_j > 0.0);
            assert!(r.pim_relieved_peak <= r.peak_needed);
        }
        // KV column reproduces the preset closed form exactly.
        for (r, m) in s.rows.iter().zip(crate::workload::spectrum_presets()) {
            assert_eq!(r.kv_bytes, m.kv_cache_bytes(68));
            assert_eq!(r.attn, m.attn_kind());
        }
    }

    #[test]
    fn fig8_alphas_cover_paper_range() {
        let ctx = ApiContext::new();
        let s1 = ExperimentSpec::builder()
            .model(crate::workload::TINY_GQA)
            .prefill(64)
            .accel(crate::config::tiny())
            .build()
            .unwrap()
            .run_stage1(&ctx)
            .unwrap();
        let f8 = fig8(&s1);
        assert_eq!(f8.alphas, vec![1.0, 0.9, 0.75, 0.5]);
        assert_eq!(f8.timelines.len(), 4);
        // Lower alpha -> no fewer active banks at any time.
        for (lo, hi) in f8.timelines[3].iter().zip(&f8.timelines[0]) {
            if lo.t0 == hi.t0 {
                assert!(lo.active >= hi.active);
            }
        }
    }
}
