//! `trapti::api` — the typed, composable entry point for the whole
//! TRAPTI flow.
//!
//! The pipeline is spec → stage handles → (optionally) parallel batch:
//!
//! 1. **[`ExperimentSpec`]** (via [`ExperimentSpec::builder`]) describes
//!    one scenario — model, workload, accelerator, optional Stage-II
//!    sweep grid — validates on `build()`, and exposes a stable
//!    [`ExperimentSpec::content_hash`] used for memoization.
//! 2. **[`Stage1Run`]** executes the cycle-level simulation and owns
//!    the occupancy traces; **[`Stage2Run`]** (obtainable only from a
//!    `&Stage1Run`, over borrowed trace views) evaluates banking and
//!    power-gating candidates. Illegal orderings (Stage II before
//!    Stage I) are unrepresentable. Streaming-only runs
//!    ([`ExperimentSpec::stream_stage1`] + a [`trace::TraceSink`])
//!    return a [`Stage1Summary`] with no Stage-II surface at all.
//! 3. **[`BatchRunner`]** executes many specs concurrently across
//!    threads, memoized by spec hash — a grid of scenarios runs as one
//!    parallel batch with byte-identical results to a sequential loop.
//! 4. **[`optimize`]** closes the loop: [`Stage2Run::optimize`] /
//!    `ServingSweep::optimize` derive an ε-Pareto frontier over
//!    (energy, activity, area) from a sweep, and [`run_portfolio`]
//!    scores configurations across *several* workloads (worst-case /
//!    mean regret) to pick the robust-best one — `repro optimize`.
//!
//! Stage I and Stage II can also run **fused**: the simulation streams
//! occupancy straight into the single-pass sweep engine
//! ([`crate::banking::SweepSink`]) so no trace is ever materialized —
//! [`ExperimentSpec::stream_stage2`] for single-sequence workloads,
//! [`ExperimentSpec::serve_fused`] for serving scenarios.
//!
//! **Stage III** closes the loop online: [`ExperimentSpec::stream_online`]
//! / [`ExperimentSpec::serve_online`] pipe the Stage-I stream into the
//! cycle-level gating co-simulator
//! ([`crate::banking::OnlineGateSim`]) for one chosen configuration,
//! [`Stage2Run::replay_online`] replays against a materialized trace,
//! and [`online_validate`] replays a whole portfolio's Pareto frontiers
//! to report predicted-vs-observed energy/stall deltas (`repro replay`,
//! `repro optimize --online-validate 1`).
//!
//! A runnable end-to-end example on the tiny preset (spec-build →
//! Stage I → Stage II sweep → optimize):
//!
//! ```
//! use trapti::api::{ApiContext, ExperimentSpec};
//! use trapti::banking::Constraints;
//! use trapti::workload::TINY_GQA;
//!
//! let ctx = ApiContext::new();
//! let spec = ExperimentSpec::builder()
//!     .model(TINY_GQA)
//!     .decode(32, 16)
//!     .accel(trapti::config::tiny())
//!     .build()
//!     .unwrap();
//! let s1 = spec.run_stage1(&ctx).unwrap();          // Stage I
//! let s2 = s1.stage2(&ctx).unwrap();                // Stage II sweep
//! let r = s2.optimize(&Constraints::default(), 0.0).unwrap();
//! assert!(!r.frontiers[0].frontier.is_empty());     // Pareto frontier
//! ```
//!
//! The paper's figure/table runners live in [`experiments`]; the
//! legacy `coordinator::Coordinator` is a thin deprecated shim over
//! this module.
//!
//! ```no_run
//! use trapti::api::{ApiContext, ExperimentSpec};
//! use trapti::workload::DS_R1D_Q15B;
//!
//! let ctx = ApiContext::new();
//! let spec = ExperimentSpec::builder()
//!     .model(DS_R1D_Q15B)
//!     .prefill(2048)
//!     .build()
//!     .unwrap();
//! let s1 = spec.run_stage1(&ctx).unwrap();
//! let s2 = s1.stage2(&ctx).unwrap(); // paper grid derived from the peak
//! println!("best dE = {:.1}%", s2.best_delta_pct());
//! ```
//!
//! [`trace::TraceSink`]: crate::trace::TraceSink

pub mod batch;
pub mod experiments;
pub mod observe;
pub mod optimize;
pub mod serving;
pub mod spec;
pub mod stage;

pub use batch::{BatchResult, BatchRunner};
pub use optimize::{
    online_validate, online_validate_with, run_portfolio, validate_frontier,
    validate_frontier_with, OnlineValidation, PortfolioOptions, PortfolioRun,
};
pub use serving::{ServingEngine, ServingRun, ServingSweep};
pub use spec::{validate_sweep, ExperimentSpec, ExperimentSpecBuilder};
pub use stage::{ApiContext, MaterializedRun, Stage1Run, Stage1Summary, Stage2Run};
