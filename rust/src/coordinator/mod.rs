//! Coordinator: the L3 orchestration layer tying Stage I (cycle-level
//! simulation) to Stage II (banking/power-gating exploration) and the
//! functional PJRT runtime — the programmatic face of the whole TRAPTI
//! flow (Fig. 3), used by the CLI, the examples, and the benches.

pub mod experiments;

use anyhow::Result;

use crate::banking::{sweep, GatingPolicy, SweepPoint, SweepSpec};
use crate::cacti::CactiModel;
use crate::config::AccelConfig;
use crate::energy::{energy_breakdown, EnergyBreakdown, EnergyParams};
use crate::memory::{size_memory, SizingResult};
use crate::sim::{simulate, SimResult};
use crate::util::MIB;
use crate::workload::{build_workload, ModelPreset, Workload, WorkloadGraph};

/// Shared context: CACTI characterization + energy coefficients.
pub struct Coordinator {
    pub cacti: CactiModel,
    pub energy: EnergyParams,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self {
            cacti: CactiModel::default(),
            energy: EnergyParams::default(),
        }
    }
}

/// Stage-I output bundle for one workload.
pub struct Stage1 {
    pub graph: WorkloadGraph,
    pub result: SimResult,
    pub energy: EnergyBreakdown,
}

impl Coordinator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the workload graph and run Stage I on `accel`.
    pub fn stage1(
        &self,
        model: &ModelPreset,
        workload: Workload,
        accel: &AccelConfig,
    ) -> Result<Stage1> {
        let graph = build_workload(model, workload)?;
        let result = simulate(&graph, accel)?;
        let energy = energy_breakdown(&result, accel, &self.cacti, &self.energy);
        Ok(Stage1 {
            graph,
            result,
            energy,
        })
    }

    /// Stage-I sizing loop (16 MiB steps, CACTI latency model).
    pub fn size(
        &self,
        model: &ModelPreset,
        workload: Workload,
        accel: &AccelConfig,
    ) -> Result<SizingResult> {
        let graph = build_workload(model, workload)?;
        let cacti = self.cacti.clone();
        size_memory(&graph, accel, 16 * MIB, &move |cap| {
            cacti.latency_cycles(cap)
        })
    }

    /// Stage-II sweep over a Stage-I result's shared-SRAM trace.
    pub fn stage2(
        &self,
        stage1: &Stage1,
        spec: &SweepSpec,
        freq_ghz: f64,
    ) -> Vec<SweepPoint> {
        sweep(
            &self.cacti,
            stage1.result.sram_trace(),
            &stage1.result.stats,
            spec,
            freq_ghz,
        )
    }

    /// Stage-II sweep for every on-chip memory of a multi-level run
    /// (Table III evaluates shared SRAM, DM1, DM2 independently).
    pub fn stage2_per_memory(
        &self,
        stage1: &Stage1,
        spec: &SweepSpec,
        freq_ghz: f64,
    ) -> Vec<(String, Vec<SweepPoint>)> {
        stage1
            .result
            .traces
            .iter()
            .enumerate()
            .map(|(i, tr)| {
                (
                    tr.memory.clone(),
                    sweep(
                        &self.cacti,
                        tr,
                        &stage1.result.per_mem_stats[i],
                        spec,
                        freq_ghz,
                    ),
                )
            })
            .collect()
    }

    /// The paper's default Stage-II grid for a trace (16 MiB steps from
    /// the workload's required capacity up to 128 MiB, B in {1..32},
    /// alpha = 0.9, aggressive gating).
    pub fn paper_spec(&self, stage1: &Stage1) -> SweepSpec {
        SweepSpec::paper_grid(stage1.result.peak_needed())
    }
}

/// Convenience re-exports for callers.
pub use crate::banking::OccupancyBasis;
pub type Policy = GatingPolicy;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::workload::TINY_GQA;

    #[test]
    fn stage1_then_stage2_composes() {
        let coord = Coordinator::new();
        let s1 = coord
            .stage1(&TINY_GQA, Workload::Prefill { seq: 64 }, &tiny())
            .unwrap();
        assert!(s1.result.feasible());
        assert!(s1.energy.total_j() > 0.0);
        let spec = SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB],
            banks: vec![1, 4, 8],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        let points = coord.stage2(&s1, &spec, 1.0);
        assert!(!points.is_empty());
        // At toy scale dynamic energy can dominate, so banking need not
        // win overall — but gating must find idle intervals and reduce
        // *leakage* energy relative to the unbanked reference.
        let best = points
            .iter()
            .filter(|p| p.eval.banks > 1)
            .min_by(|a, b| a.eval.e_leak_j.total_cmp(&b.eval.e_leak_j))
            .unwrap();
        let base = points.iter().find(|p| p.eval.banks == 1).unwrap();
        assert!(best.eval.gated_fraction > 0.0, "no idle intervals found");
        assert!(best.eval.e_leak_j < base.eval.e_leak_j);
    }

    #[test]
    fn sizing_composes_with_cacti_latency() {
        let coord = Coordinator::new();
        let r = coord
            .size(&TINY_GQA, Workload::Prefill { seq: 64 }, &tiny())
            .unwrap();
        assert!(r.verify.feasible());
        assert_eq!(r.required_capacity % (16 * MIB), 0);
    }
}
