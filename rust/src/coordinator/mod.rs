//! Legacy orchestration shim.
//!
//! The `Coordinator` was the original ad-hoc programmatic surface
//! (loose `stage1`/`stage2`/`size` methods). It is now a thin
//! **deprecated** wrapper over [`crate::api`] — the typed pipeline
//! (`ExperimentSpec` → `Stage1Run` → `Stage2Run`, plus `BatchRunner`
//! for parallel grids). New code should use `trapti::api` directly; the
//! CLI, benches, examples and tests already do.

#![allow(deprecated)]

pub mod experiments;

use anyhow::Result;

use crate::api::{ApiContext, ExperimentSpec};
use crate::banking::{sweep, GatingPolicy, SweepPoint, SweepSpec};
use crate::cacti::CactiModel;
use crate::config::AccelConfig;
use crate::energy::EnergyParams;
use crate::memory::SizingResult;
use crate::workload::{ModelPreset, Workload};

/// Stage-I output bundle — now the api type (same `graph` / `result` /
/// `energy` fields, plus the originating `spec`).
pub type Stage1 = crate::api::Stage1Run;

/// Shared context: CACTI characterization + energy coefficients.
#[deprecated(
    since = "0.1.0",
    note = "use `trapti::api` — ExperimentSpec::builder() → run_stage1 → \
            stage2 (or BatchRunner for parallel grids)"
)]
pub struct Coordinator {
    pub cacti: CactiModel,
    pub energy: EnergyParams,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self {
            cacti: CactiModel::default(),
            energy: EnergyParams::default(),
        }
    }
}

impl Coordinator {
    pub fn new() -> Self {
        Self::default()
    }

    fn ctx(&self) -> ApiContext {
        ApiContext {
            cacti: self.cacti.clone(),
            energy: self.energy.clone(),
        }
    }

    fn spec(
        model: &ModelPreset,
        workload: Workload,
        accel: &AccelConfig,
    ) -> Result<ExperimentSpec> {
        ExperimentSpec::builder()
            .model(model.clone())
            .workload(workload)
            .accel(accel.clone())
            .build()
    }

    /// Build the workload graph and run Stage I on `accel`.
    pub fn stage1(
        &self,
        model: &ModelPreset,
        workload: Workload,
        accel: &AccelConfig,
    ) -> Result<Stage1> {
        Self::spec(model, workload, accel)?.run_stage1(&self.ctx())
    }

    /// Stage-I sizing loop (16 MiB steps, CACTI latency model).
    pub fn size(
        &self,
        model: &ModelPreset,
        workload: Workload,
        accel: &AccelConfig,
    ) -> Result<SizingResult> {
        Self::spec(model, workload, accel)?.size_memory(&self.ctx())
    }

    /// Stage-II sweep over a Stage-I result's shared-SRAM trace.
    pub fn stage2(
        &self,
        stage1: &Stage1,
        spec: &SweepSpec,
        freq_ghz: f64,
    ) -> Result<Vec<SweepPoint>> {
        Ok(sweep(
            &self.cacti,
            stage1.result.sram_trace(),
            &stage1.result.stats,
            spec,
            freq_ghz,
        )?)
    }

    /// Stage-II sweep for every on-chip memory of a multi-level run
    /// (Table III evaluates shared SRAM, DM1, DM2 independently).
    /// Traces zip defensively with their per-memory statistics — a
    /// length mismatch evaluates the common prefix instead of panicking.
    pub fn stage2_per_memory(
        &self,
        stage1: &Stage1,
        spec: &SweepSpec,
        freq_ghz: f64,
    ) -> Result<Vec<(String, Vec<SweepPoint>)>> {
        stage1
            .result
            .traces
            .iter()
            .zip(stage1.result.per_mem_stats.iter())
            .map(|(tr, st)| {
                Ok((
                    tr.memory.clone(),
                    sweep(&self.cacti, tr, st, spec, freq_ghz)?,
                ))
            })
            .collect()
    }

    /// The paper's default Stage-II grid for a trace (16 MiB steps from
    /// the workload's required capacity up to 128 MiB, B in {1..32},
    /// alpha = 0.9, aggressive gating).
    pub fn paper_spec(&self, stage1: &Stage1) -> SweepSpec {
        SweepSpec::paper_grid(stage1.result.peak_needed())
    }
}

/// Convenience re-exports for callers.
pub use crate::banking::OccupancyBasis;
pub type Policy = GatingPolicy;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{multilevel, tiny};
    use crate::util::MIB;
    use crate::workload::TINY_GQA;

    fn small_grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB],
            banks: vec![1, 4, 8],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        }
    }

    #[test]
    fn shim_matches_api_numbers() {
        let coord = Coordinator::new();
        let s1 = coord
            .stage1(&TINY_GQA, Workload::Prefill { seq: 64 }, &tiny())
            .unwrap();
        assert!(s1.result.feasible());

        let api_s1 = ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap()
            .run_stage1(&ApiContext::new())
            .unwrap();
        assert_eq!(s1.result.total_cycles, api_s1.result.total_cycles);
        assert_eq!(s1.result.stats, api_s1.result.stats);

        let pts = coord.stage2(&s1, &small_grid(), 1.0).unwrap();
        let api_pts = api_s1
            .stage2_with(&ApiContext::new(), &small_grid())
            .unwrap();
        assert_eq!(pts.len(), api_pts.shared().len());
        for (a, b) in pts.iter().zip(api_pts.shared()) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
        }
    }

    #[test]
    fn stage2_per_memory_survives_length_mismatch() {
        let coord = Coordinator::new();
        let mut s1 = coord
            .stage1(&TINY_GQA, Workload::Prefill { seq: 64 }, &multilevel())
            .unwrap();
        assert_eq!(s1.result.traces.len(), 3);
        let full = coord.stage2_per_memory(&s1, &small_grid(), 1.0).unwrap();
        assert_eq!(full.len(), 3);
        // Divergent lengths must not panic (the old implementation
        // indexed per_mem_stats[i] and did).
        s1.result.per_mem_stats.truncate(2);
        let partial = coord.stage2_per_memory(&s1, &small_grid(), 1.0).unwrap();
        assert_eq!(partial.len(), 2);
    }
}
