//! Legacy path for the per-experiment runners.
//!
//! The figure/table runners moved to [`crate::api::experiments`] as part
//! of the `trapti::api` migration (they now take an
//! [`crate::api::ApiContext`] and run paired Stage-I simulations as one
//! parallel batch). This module re-exports them so older
//! `coordinator::experiments::*` paths keep resolving.

pub use crate::api::experiments::*;
