//! Minimal property-testing harness (no proptest crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases`
//! independently seeded deterministic RNGs. On failure it reports the
//! failing seed so the case replays exactly with `replay(seed, f)`.
//! No shrinking — generators here are kept small and structured so raw
//! failing seeds are already debuggable.

use super::rng::Rng;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed on
/// the first property violation (assert inside `f`).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        // Spread seeds so adjacent cases are decorrelated.
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (replay seed \
                 {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn replay<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check("sum-commutes", 50, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let err = std::panic::catch_unwind(|| {
            check("always-fails", 3, |rng| {
                let v = rng.below(10);
                assert!(v > 100, "v={v} not > 100");
            });
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("always-fails"), "{msg}");
    }

    #[test]
    fn replay_reproduces() {
        let mut first = None;
        check("record", 1, |rng| {
            first = Some(rng.next_u64());
        });
        let seed = 0xC0FFEE ^ 0u64;
        replay(seed, |rng| {
            assert_eq!(rng.next_u64(), first.unwrap());
        });
    }
}
