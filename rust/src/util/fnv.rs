//! FNV-1a, 64-bit: tiny, dependency-free, stable across platforms.
//!
//! Shared by every content hash in the crate (`ExperimentSpec`
//! memoization keys, serving-trace determinism fingerprints) so the two
//! can never drift onto different hash functions.

#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed string hashing (unambiguous concatenation).
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sensitive() {
        let mut a = Fnv64::new();
        a.str("trapti");
        a.u64(42);
        let mut b = Fnv64::new();
        b.str("trapti");
        b.u64(42);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.str("trapti");
        c.u64(43);
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn length_prefix_disambiguates() {
        let mut a = Fnv64::new();
        a.str("ab");
        a.str("c");
        let mut b = Fnv64::new();
        b.str("a");
        b.str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
