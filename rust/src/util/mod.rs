//! Self-contained substrate utilities (the offline environment provides no
//! serde/clap/rand/proptest, so the crate carries minimal equivalents).

pub mod bench;
pub mod fnv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;

pub use fnv::Fnv64;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * 1024;
pub const GIB: u64 = 1024 * 1024 * 1024;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
