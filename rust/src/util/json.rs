//! Minimal JSON parser + writer (no serde available offline).
//!
//! Consumes `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and serializes simulation traces / reports. Supports the full JSON
//! grammar except for exotic number forms beyond f64, which is all the
//! manifest and reports need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a BTreeMap so emission order (and therefore
/// report files) is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing wants loud
    /// failures, not silent Nones.
    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- emission ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| if pretty { "  ".repeat(n) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                out.push_str(nl);
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad(indent + 1));
                    item.write(out, indent + 1, pretty);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&pad(indent));
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                out.push_str(nl);
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad(indent + 1));
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&pad(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry byte offsets for debuggability.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, want: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == want => Ok(()),
            other => bail!(
                "expected `{}` at byte {}, got {:?}",
                want as char,
                self.pos.saturating_sub(1),
                other.map(|b| b as char)
            ),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number `{text}` at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // Surrogate pairs: JSON encodes astral chars as
                        // \uD8xx\uDCxx.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            self.expect_byte(b'\\')?;
                            self.expect_byte(b'u')?;
                            let low = self.hex4()?;
                            let combined =
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(code)
                        };
                        out.push(c.ok_or_else(|| anyhow!("bad \\u escape"))?);
                    }
                    other => bail!("bad escape {:?}", other.map(|b| b as char)),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: re-decode from the source slice.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = start + width;
                    if end > self.bytes.len() {
                        bail!("truncated UTF-8 at byte {start}");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| anyhow!("eof in \\u escape"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| anyhow!("bad hex digit `{}`", c as char))?;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected , or ] got {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected , or }} got {:?}", other.map(|b| b as char)),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo → 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"arr":[1,2.5,true,null],"obj":{"k":"v \"q\""},"s":"x"}"#;
        let v = parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn real_manifest_parses() {
        // Integration sanity against the actual artifact manifest when
        // present (built by `make artifacts`).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert!(m.get("entries").is_some());
        }
    }

    #[test]
    fn u64_roundtrip_precision() {
        let v = parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(v.as_u64().unwrap(), 9007199254740991);
        assert_eq!(Json::num(123.0).to_string_compact(), "123");
    }
}
