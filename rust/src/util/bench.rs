//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each bench target (rust/benches/*.rs, `harness = false`) regenerates
//! one paper table/figure through `api::experiments` and times
//! the end-to-end generation with warmup + repeated measurement,
//! reporting mean / min / max / stddev like criterion's summary line.
//!
//! Perf trajectory: benches also [`emit_json`] a `BENCH_<name>.json`
//! artifact (wall-ms, derived ratios, problem size) under
//! `TRAPTI_BENCH_DIR`. CI runs the benches in smoke mode
//! (`TRAPTI_BENCH_SMOKE=1`, shrunken workloads), uploads the artifacts,
//! and `repro bench check` compares them against the committed
//! `rust/configs/bench_baseline.json` with generous tolerances
//! ([`baseline_violations`]) — a trajectory of the hot path's cost over
//! time, not a microbenchmark gate.

use std::io;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<28} iters={:<3} mean={:>10.3?} min={:>10.3?} \
             max={:>10.3?} stddev={:>9.3?}",
            self.name, self.iters, self.mean, self.min, self.max, self.stddev
        );
    }
}

/// Time `f` with one warmup run and `iters` measured runs. The closure's
/// output is returned from the *last* run so benches can render the
/// regenerated table after timing.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    let _warmup = f();
    let mut samples = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed());
        last = Some(out);
    }
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / iters as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        min,
        max,
        stddev: Duration::from_secs_f64(var.sqrt()),
    };
    result.report();
    (result, last.expect("iters >= 1"))
}

/// Iteration count from `TRAPTI_BENCH_ITERS` (default 3; CI may use 1).
pub fn default_iters() -> usize {
    std::env::var("TRAPTI_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
        .max(1)
}

/// `TRAPTI_BENCH_SMOKE=1` shrinks bench workloads to CI scale: same code
/// paths and correctness assertions, wall-clock in seconds not minutes.
/// Speedup-threshold assertions that only hold at full scale are gated
/// off in smoke mode (the JSON artifact still records the ratio).
pub fn smoke() -> bool {
    std::env::var("TRAPTI_BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// Directory `BENCH_*.json` artifacts land in (`TRAPTI_BENCH_DIR`,
/// default: the working directory).
pub fn bench_dir() -> PathBuf {
    std::env::var_os("TRAPTI_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."))
}

impl BenchResult {
    /// Timing fields as JSON (milliseconds), for [`emit_json`].
    pub fn to_json(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("iters", Json::num(self.iters as f64)),
            ("wall_ms", Json::num(self.mean.as_secs_f64() * 1e3)),
            ("min_ms", Json::num(self.min.as_secs_f64() * 1e3)),
            ("max_ms", Json::num(self.max.as_secs_f64() * 1e3)),
        ]
    }
}

/// Write `BENCH_<name>.json` under [`bench_dir`]: the `name` field plus
/// `fields`, keys emitted in `Json::obj`'s sorted order. Returns the
/// written path. Benches call this once, after their correctness
/// assertions pass.
pub fn emit_json(name: &str, fields: Vec<(&str, Json)>) -> io::Result<PathBuf> {
    write_json_to(&bench_dir(), name, fields)
}

/// [`emit_json`] with an explicit directory (testable without env races).
pub fn write_json_to(
    dir: &Path,
    name: &str,
    fields: Vec<(&str, Json)>,
) -> io::Result<PathBuf> {
    let mut pairs = vec![("name", Json::str(name))];
    pairs.extend(fields);
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::create_dir_all(dir)?;
    std::fs::write(&path, format!("{}\n", Json::obj(pairs).to_string_pretty()))?;
    Ok(path)
}

/// Compare one bench artifact against its baseline rules. Each rule is
/// `max_<field>` (artifact field must be `<=` the bound) or
/// `min_<field>` (`>=`); unknown rule shapes and missing/non-numeric
/// artifact fields are violations too, so a malformed baseline cannot
/// silently pass. Returns human-readable violation lines (empty = ok).
pub fn baseline_violations(artifact: &Json, rules: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let Some(rules) = rules.as_obj() else {
        return vec!["baseline entry is not an object".to_string()];
    };
    for (rule, bound) in rules {
        let Some(bound) = bound.as_f64() else {
            out.push(format!("baseline rule `{rule}` is not numeric"));
            continue;
        };
        let (field, is_max) = if let Some(f) = rule.strip_prefix("max_") {
            (f, true)
        } else if let Some(f) = rule.strip_prefix("min_") {
            (f, false)
        } else {
            out.push(format!(
                "baseline rule `{rule}` must start with max_ or min_"
            ));
            continue;
        };
        let Some(value) = artifact.get(field).and_then(Json::as_f64) else {
            out.push(format!("artifact is missing numeric field `{field}`"));
            continue;
        };
        if is_max && value > bound {
            out.push(format!("{field} = {value} exceeds max {bound}"));
        } else if !is_max && value < bound {
            out.push(format!("{field} = {value} below min {bound}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_output_and_stats() {
        let (r, out) = bench("noop", 5, || 42);
        assert_eq!(out, 42);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean && r.mean <= r.max + Duration::from_nanos(1));
    }

    #[test]
    fn default_iters_floor() {
        assert!(default_iters() >= 1);
    }

    #[test]
    fn emit_json_writes_named_artifact() {
        let dir = std::env::temp_dir()
            .join(format!("trapti-bench-emit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (r, _) = bench("unit_emit", 2, || 1 + 1);
        let mut fields = r.to_json();
        fields.push(("grid_points", Json::num(144.0)));
        let path = write_json_to(&dir, "unit_emit", fields).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_unit_emit.json");
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("unit_emit"));
        assert_eq!(parsed.get("grid_points").unwrap().as_f64(), Some(144.0));
        assert!(parsed.get("wall_ms").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn baseline_violations_bounds_and_malformed_rules() {
        let artifact = Json::obj(vec![
            ("name", Json::str("stage2_sweep")),
            ("wall_ms", Json::num(50.0)),
            ("speedup_vs_naive", Json::num(8.0)),
        ]);
        // In bounds: no violations.
        let ok = Json::obj(vec![
            ("max_wall_ms", Json::num(100.0)),
            ("min_speedup_vs_naive", Json::num(2.0)),
        ]);
        assert!(baseline_violations(&artifact, &ok).is_empty());
        // Out of bounds both directions.
        let bad = Json::obj(vec![
            ("max_wall_ms", Json::num(10.0)),
            ("min_speedup_vs_naive", Json::num(20.0)),
        ]);
        let v = baseline_violations(&artifact, &bad);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|m| m.contains("wall_ms") && m.contains("exceeds")));
        assert!(v.iter().any(|m| m.contains("below min")));
        // Malformed rules and missing fields are loud, not silent passes.
        let malformed = Json::obj(vec![
            ("wall_ms", Json::num(10.0)),
            ("max_nonexistent", Json::num(1.0)),
            ("max_name", Json::num(1.0)),
        ]);
        let v = baseline_violations(&artifact, &malformed);
        assert_eq!(v.len(), 3, "{v:?}");
        assert!(baseline_violations(&artifact, &Json::num(1.0)).len() == 1);
    }
}
