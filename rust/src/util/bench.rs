//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each bench target (rust/benches/*.rs, `harness = false`) regenerates
//! one paper table/figure through `api::experiments` and times
//! the end-to-end generation with warmup + repeated measurement,
//! reporting mean / min / max / stddev like criterion's summary line.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<28} iters={:<3} mean={:>10.3?} min={:>10.3?} \
             max={:>10.3?} stddev={:>9.3?}",
            self.name, self.iters, self.mean, self.min, self.max, self.stddev
        );
    }
}

/// Time `f` with one warmup run and `iters` measured runs. The closure's
/// output is returned from the *last* run so benches can render the
/// regenerated table after timing.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    let _warmup = f();
    let mut samples = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed());
        last = Some(out);
    }
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / iters as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        min,
        max,
        stddev: Duration::from_secs_f64(var.sqrt()),
    };
    result.report();
    (result, last.expect("iters >= 1"))
}

/// Iteration count from `TRAPTI_BENCH_ITERS` (default 3; CI may use 1).
pub fn default_iters() -> usize {
    std::env::var("TRAPTI_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_output_and_stats() {
        let (r, out) = bench("noop", 5, || 42);
        assert_eq!(out, 42);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean && r.mean <= r.max + Duration::from_nanos(1));
    }

    #[test]
    fn default_iters_floor() {
        assert!(default_iters() >= 1);
    }
}
