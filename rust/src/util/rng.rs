//! Deterministic PRNG (SplitMix64 + xoshiro256**).
//!
//! The offline environment has no `rand` crate, so the crate carries its
//! own generator. Used by the property tests, the workload fuzzers, and
//! the synthetic-input generation for the PJRT runtime examples.
//! Deterministic by construction: every consumer seeds explicitly, so
//! simulation runs and test failures reproduce bit-exactly.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna reference
/// constants). Not cryptographic; statistical quality is ample for
/// synthetic workloads and property-test case generation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// variant (bias < 2^-64, irrelevant at our scales).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast
    /// here — only used to fill synthetic tensors).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a buffer with scaled normals (synthetic weights for the
    /// functional runtime path).
    pub fn fill_normal_f32(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random pick from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi, "endpoints should be reachable");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
