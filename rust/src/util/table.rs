//! Plain-text table and ASCII line-plot rendering for paper-style reports.
//!
//! The `repro report <exp>` subcommands print tables whose rows mirror the
//! paper's Tables I-III and series that mirror Figs. 1-9; this module is
//! their shared presentation layer (plus CSV emission for plotting).

use std::fmt::Write as _;

/// Column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "{}", self.title);
        }
        let _ = writeln!(out, "{sep}");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let _ = write!(line, "| {:>w$} ", cells[i], w = widths[i]);
            }
            line + "|"
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// ASCII line plot of one or more named series sharing an x axis.
/// Good enough to eyeball the occupancy traces (Fig. 5/8) in a terminal;
/// exact values go to CSV alongside.
pub struct AsciiPlot {
    pub title: String,
    pub width: usize,
    pub height: usize,
    pub series: Vec<(String, Vec<(f64, f64)>)>,
    pub y_label: String,
    pub x_label: String,
}

impl AsciiPlot {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            width: 100,
            height: 20,
            series: Vec::new(),
            y_label: String::new(),
            x_label: String::new(),
        }
    }

    pub fn series(mut self, name: &str, pts: Vec<(f64, f64)>) -> Self {
        self.series.push((name.to_string(), pts));
        self
    }

    pub fn labels(mut self, x: &str, y: &str) -> Self {
        self.x_label = x.to_string();
        self.y_label = y.to_string();
        self
    }

    pub fn render(&self) -> String {
        let marks = ['*', 'o', '+', 'x', '#', '@'];
        let all: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|(_, pts)| pts.iter().copied())
            .collect();
        if all.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (0.0f64, f64::NEG_INFINITY);
        for &(x, y) in &all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if (xmax - xmin).abs() < f64::EPSILON {
            xmax = xmin + 1.0;
        }
        if (ymax - ymin).abs() < f64::EPSILON {
            ymax = ymin + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, pts)) in self.series.iter().enumerate() {
            let mark = marks[si % marks.len()];
            // Step-interpolate between points so piecewise-constant traces
            // (occupancy) render as filled lines, not sparse dots.
            for w in pts.windows(2).chain(std::iter::once(&pts[pts.len() - 1..])) {
                let (x0, y0) = w[0];
                let x1 = w.get(1).map(|p| p.0).unwrap_or(x0);
                let c0 = (((x0 - xmin) / (xmax - xmin)) * (self.width - 1) as f64)
                    .round() as usize;
                let c1 = (((x1 - xmin) / (xmax - xmin)) * (self.width - 1) as f64)
                    .round() as usize;
                let r = ((1.0 - (y0 - ymin) / (ymax - ymin))
                    * (self.height - 1) as f64)
                    .round() as usize;
                for c in c0..=c1.min(self.width - 1) {
                    grid[r.min(self.height - 1)][c] = mark;
                }
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.title);
        let legend: Vec<String> = self
            .series
            .iter()
            .enumerate()
            .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
            .collect();
        let _ = writeln!(out, "  [{}]   y: {}", legend.join("  "), self.y_label);
        for (i, row) in grid.iter().enumerate() {
            let yv = ymax - (ymax - ymin) * i as f64 / (self.height - 1) as f64;
            let _ = writeln!(out, "{:>10.1} |{}", yv, row.iter().collect::<String>());
        }
        let _ = writeln!(
            out,
            "{:>10} +{}",
            "",
            "-".repeat(self.width)
        );
        let _ = writeln!(
            out,
            "{:>10}  {:<.1}{:>w$.1}   x: {}",
            "",
            xmin,
            xmax,
            self.x_label,
            w = self.width - format!("{xmin:.1}").len()
        );
        out
    }
}

/// Human-readable byte size (MiB with 1 decimal, matching paper style).
pub fn fmt_mib(bytes: u64) -> String {
    format!("{:.1} MiB", bytes as f64 / (1024.0 * 1024.0))
}

/// Format a signed percentage delta like the paper's ΔE/ΔA columns.
/// A zero or non-finite base has no meaningful relative delta; render
/// `–` (the paper's empty-cell dash) rather than `NaN`/`inf`, so a
/// degenerate sweep can never corrupt a rendered artifact.
pub fn fmt_delta_pct(new: f64, base: f64) -> String {
    if base == 0.0 || !base.is_finite() || !new.is_finite() {
        return "–".into();
    }
    let pct = (new - base) / base * 100.0;
    format!("{:+.1}", pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("| 100 |"));
        assert!(s.lines().all(|l| l.len() <= 20));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("T", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c"));
        assert!(csv.contains("\"x\"\"y\""));
    }

    #[test]
    fn plot_renders_without_panic() {
        let p = AsciiPlot::new("demo")
            .series("s", vec![(0.0, 0.0), (1.0, 5.0), (2.0, 3.0)])
            .labels("t", "occ");
        let s = p.render();
        assert!(s.contains('*'));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_mib(107 * 1024 * 1024 + 300 * 1024), "107.3 MiB");
        assert_eq!(fmt_delta_pct(90.0, 100.0), "-10.0");
        assert_eq!(fmt_delta_pct(110.0, 100.0), "+10.0");
        // Degenerate bases render the paper's dash, never NaN/inf.
        assert_eq!(fmt_delta_pct(1.0, 0.0), "–");
        assert_eq!(fmt_delta_pct(f64::NAN, 100.0), "–");
        assert_eq!(fmt_delta_pct(1.0, f64::INFINITY), "–");
    }
}
