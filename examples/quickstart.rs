//! Quickstart: the complete TRAPTI two-stage flow on one workload in
//! ~40 lines of user code.
//!
//! Stage I simulates DeepSeek-R1-Distill-Qwen-1.5B prefill (M=2048) on
//! the paper's baseline accelerator and extracts the time-resolved SRAM
//! occupancy trace; Stage II sweeps banked organizations with power
//! gating and prints the energy/area candidates.
//!
//! Run: `cargo run --release --example quickstart`

use trapti::banking::{GatingPolicy, SweepSpec};
use trapti::config::baseline;
use trapti::coordinator::Coordinator;
use trapti::util::MIB;
use trapti::workload::{Workload, DS_R1D_Q15B};

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::new();
    let accel = baseline();

    // --- Stage I: cycle-level simulation + occupancy trace ------------
    let s1 = coord.stage1(&DS_R1D_Q15B, Workload::Prefill { seq: 2048 }, &accel)?;
    println!("{}", s1.graph.summary());
    println!(
        "Stage I: {:.1} ms simulated, peak needed {:.1} MiB, \
         {} SRAM reads, feasible={}",
        s1.result.seconds() * 1e3,
        s1.result.peak_needed() as f64 / MIB as f64,
        s1.result.stats.reads,
        s1.result.feasible(),
    );

    // --- Stage II: banking + power-gating exploration ------------------
    let spec = SweepSpec {
        capacities: vec![48 * MIB, 64 * MIB, 128 * MIB],
        banks: vec![1, 4, 8, 16],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::Aggressive],
    };
    println!("\nStage II (alpha=0.9, aggressive gating):");
    println!(
        "{:>8} {:>6} {:>12} {:>8} {:>12}",
        "C[MiB]", "banks", "E_total[J]", "dE%", "area[mm2]"
    );
    for p in coord.stage2(&s1, &spec, accel.sa.freq_ghz) {
        println!(
            "{:>8} {:>6} {:>12.2} {:>8.1} {:>12.1}",
            p.eval.capacity / MIB,
            p.eval.banks,
            p.eval.e_total_j(),
            p.delta_e_pct(),
            p.eval.area_mm2,
        );
    }
    Ok(())
}
